"""§Perf bench: SeqBalance multi-path grad sync vs stock XLA all-reduce —
collective op counts/bytes from lowered HLO on an 8-device subprocess."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import PERF, emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = textwrap.dedent("""
    import json, re
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import PathPlan, seqbalance_all_reduce
    from repro.launch.dryrun import collective_bytes

    mesh = jax.make_mesh((8,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.zeros((8, 1 << 20), jnp.float32)  # 4 MB bucket per device

    def seq(x):
        return seqbalance_all_reduce(x, "pod", PathPlan(n_chunks=4, wire_dtype="%s"))

    def base(x):
        return jax.lax.psum(x, "pod")

    out = {}
    for name, fn in (("seqbalance", seq), ("baseline", base)):
        g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        hlo = g.lower(x).compile().as_text()
        out[name] = collective_bytes(hlo)
    print(json.dumps(out))
""")


def bench_collectives(fast=True):
    for wire in ("float32", "bfloat16"):
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = SRC
        r = subprocess.run([sys.executable, "-c", _CODE % wire], capture_output=True,
                           text=True, env=env, timeout=600)
        if r.returncode != 0:
            # a crashed subprocess may die before writing anything to stderr
            err_lines = r.stderr.strip().splitlines()
            why = err_lines[-1][:80] if err_lines else f"exit_{r.returncode}_no_stderr"
            emit(f"collectives_{wire}", 0.0, "FAILED_" + why)
            continue
        res = json.loads(r.stdout.strip().splitlines()[-1])
        sb, bl = res["seqbalance"], res["baseline"]
        emit(f"collectives_seqbalance_{wire}", 0.0,
             f"permute_ops_{sb['count']}_bytes_{sb['total']:.3e}")
        emit(f"collectives_baseline_{wire}", 0.0,
             f"allreduce_ops_{bl['count']}_bytes_{bl['total']:.3e}")
        if bl["total"]:
            emit(f"collectives_byte_ratio_{wire}", 0.0,
                 f"seq/base_{sb['total']/bl['total']:.2f}")
        # machine-readable record for BENCH_netsim.json (counts/bytes only —
        # the CI gate stays timing-free for this bench)
        PERF.setdefault("collectives", {})[wire] = {
            "seqbalance_ops": sb["count"], "seqbalance_bytes": sb["total"],
            "baseline_ops": bl["count"], "baseline_bytes": bl["total"],
            "byte_ratio": (sb["total"] / bl["total"]) if bl["total"] else None,
        }
