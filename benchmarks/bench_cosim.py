"""Fig. 11 at paper scale: multi-epoch co-simulation convergence.

One killed-spine scenario per (topology, scheme, ring size): the driver
(``dist.cosim.run_cosim``) iterates plan -> collective ring trace ->
fluid sim -> congestion reports -> next plan over a kill/recover fault
schedule, and this bench records the convergence story into
BENCH_netsim.json under ``"cosim"``:

  * per-epoch censored p99 FCT / completion / plan churn / quarantine
    size curves (the Fig. 11 time series, in planning epochs);
  * ``convergence_epochs`` — epochs from the kill until p99 is back
    within 10 % of the pre-failure baseline with full completion
    (gated by scripts/check_bench.py: +1 epoch regression fails CI);
  * ``rebuilds_after_first`` — sweep executables built after epoch 0,
    which the traced-capacity contract pins to 0 (also gated);
  * FCT + imbalance CDFs (metrics.cdf via CosimHistory) comparing the
    healthy, failed, and quarantined-rerouted phases.

Fast mode runs the acceptance row — paper-scale ``three_tier`` (320
hosts, 320 paths), ring of 20 ToR gateways, killed aggregation switch —
plus a 2-tier (scheme x ring) slice; ``--full`` fans the whole
(scheme x ring size in 8..64 x killed spine) grid through
``dist.cosim.run_cosim_grid`` on the sweep runner's job pool.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import PERF, emit


def _scenario(topo, topo_name, scheme, ring, *, size_bytes, kill_epoch=2,
              recover_epoch=6, epochs=10, spine=3, phi_steps=2, n_chunks=4,
              seed=0):
    """Spec dict for one killed-spine convergence run (run_cosim kwargs
    plus the labels the record keeps)."""
    from repro.dist import cosim

    spec = dict(
        topo=topo, hosts=cosim.ring_hosts(topo, ring), size_bytes=size_bytes,
        scheme=scheme, epochs=epochs, phi_steps=phi_steps, n_chunks=n_chunks,
        seed=seed,
        faults=(cosim.kill_spine(topo, spine, epoch=kill_epoch,
                                 recover_epoch=recover_epoch),),
    )
    labels = dict(topo=topo_name, scheme=scheme, ring=ring, spine=spine,
                  kill_epoch=kill_epoch, recover_epoch=recover_epoch,
                  seed=seed)
    return spec, labels


def _row(hist, labels, wall_s, solo=False):
    conv = hist.convergence_epoch(labels["kill_epoch"])
    rec = hist.as_record()
    rec.update(labels)
    rec["baseline_p99_us"] = round(hist.baseline_p99(labels["kill_epoch"]) * 1e6, 2)
    rec["convergence_epochs"] = (None if conv is None
                                 else conv - labels["kill_epoch"])
    if solo:
        # new_builds attribution is a process-global counter delta, clean
        # only when the scenario ran alone — concurrent grid workers
        # interleave their epoch-0 compiles, so grid rows omit the key and
        # the CI gate (check_bench --cosim) only reads it where it means
        # something
        rec["rebuilds_after_first"] = int(sum(rec["new_builds"][1:]))
    rec["wall_s"] = round(wall_s, 1)
    return rec


def _cdfs(hist, labels):
    """Healthy / failed / rerouted FCT CDFs + whole-run imbalance CDF."""
    k = labels["kill_epoch"]
    quarantined = [r.epoch for r in hist.records if r.quarantined]
    phases = {
        "healthy": [e for e in range(k)],
        "failed": [k],
        "rerouted": quarantined or [min(k + 1, hist.epochs - 1)],
    }
    out = {}
    for name, eps in phases.items():
        xs, ys = hist.fct_cdf(epochs=eps, points=32)
        out[f"fct_us_{name}"] = [np.round(xs * 1e6, 2).tolist(),
                                 np.round(ys, 4).tolist()]
    xs, ys = hist.imbalance_cdf(points=32)
    out["imbalance"] = [np.round(xs, 4).tolist(), np.round(ys, 4).tolist()]
    return out


def bench_cosim(fast=True):
    from repro.dist import cosim
    from repro.netsim import sweep, topology

    rows, cdfs = [], {}

    # ---- acceptance row: paper-scale three_tier, killed agg switch.
    # Run it FIRST and alone so rebuilds_after_first attribution is clean
    # (run_cosim_grid's worker threads interleave their builds).
    topo3 = topology.three_tier()  # 320 hosts, 320 paths
    spec, labels = _scenario(topo3, "three_tier_320", "ecmp", 20,
                             size_bytes=16e6)
    t0 = time.time()
    hist = cosim.run_cosim(**spec)
    wall = time.time() - t0
    row = _row(hist, labels, wall, solo=True)
    rows.append(row)
    cdfs["three_tier_320_ecmp_r20"] = _cdfs(hist, labels)
    emit("cosim_three_tier320_ecmp_ring20", wall * 1e6,
         f"conv_epochs_{row['convergence_epochs']}_p99base_"
         f"{row['baseline_p99_us']:.0f}us_rebuilds_{row['rebuilds_after_first']}")

    # ---- (scheme x ring) grid on the 2-tier sim fabric through run_jobs
    topo2 = topology.leaf_spine(8, 12, 16, 100e9)  # paper §IV.B 2-tier
    if fast:
        grid = [("ecmp", 8), ("seqbalance", 8)]
        grid3 = []
        seeds = (0,)
    else:
        grid = [(s, r) for s in ("seqbalance", "ecmp", "letflow", "conga",
                                 "drill")
                for r in (8, 16, 32, 64)]
        grid3 = [(s, r) for s in ("seqbalance", "ecmp", "letflow")
                 for r in (8, 20, 64)]
        seeds = (0, 1)
    jobs, job_labels = [], []
    for seed in seeds:
        for scheme, ring in grid:
            spec, labels = _scenario(topo2, "leaf_spine_128", scheme, ring,
                                     size_bytes=8e6, spine=3, seed=seed)
            jobs.append(spec)
            job_labels.append(labels)
        for scheme, ring in grid3:
            spec, labels = _scenario(topo3, "three_tier_320", scheme, ring,
                                     size_bytes=16e6, seed=seed)
            jobs.append(spec)
            job_labels.append(labels)
    t0 = time.time()
    hists = cosim.run_cosim_grid(jobs)
    grid_wall = time.time() - t0
    for hist, labels in zip(hists, job_labels):
        row = _row(hist, labels, grid_wall / max(len(jobs), 1))
        rows.append(row)
        emit(f"cosim_{labels['topo']}_{labels['scheme']}_ring{labels['ring']}"
             f"_s{labels['seed']}",
             grid_wall / max(len(jobs), 1) * 1e6,
             f"conv_epochs_{row['convergence_epochs']}_p99base_"
             f"{row['baseline_p99_us']:.0f}us")

    PERF["cosim"] = dict(
        sweep_config=dict(devices=sweep.sweep_devices(),
                          batch_mode=sweep.batch_mode()),
        rows=rows,
        cdfs=cdfs,
    )


# ------------------------------------------------------------ chaos campaign
def _campaign_scenario(topo, topo_name, scheme, ring, *, size_bytes,
                       seed=0, epochs=10):
    """One mixed chaos campaign (ISSUE 6): a mid-epoch flap KILL that
    forces in-epoch replanning, a lossy spine driving go-back-N
    amplification, and a gating straggler — all on one ring."""
    from repro.dist import cosim
    from repro.netsim import faults
    from repro.netsim.topology import spine_links

    n_spines = topo.uplink_ids.shape[1]
    camp = faults.FaultCampaign(events=(
        faults.LinkFlap(links=spine_links(topo, 3 % n_spines), start_epoch=2,
                        end_epoch=6, duty=1.0, onset_frac=0.02, scale=0.0),
        faults.LossyLink(links=spine_links(topo, 5 % n_spines),
                         loss_rate=0.01, start_epoch=3, end_epoch=7),
        faults.Straggler(rank=ring // 2, slowdown=3.0, start_epoch=4,
                         end_epoch=7),
    ))
    spec = dict(
        topo=topo, hosts=cosim.ring_hosts(topo, ring), size_bytes=size_bytes,
        scheme=scheme, epochs=epochs, phi_steps=2, cooldown_steps=2,
        n_chunks=4, seed=seed, campaign=camp,
    )
    labels = dict(topo=topo_name, scheme=scheme, ring=ring, seed=seed,
                  kill_epoch=2, campaign=camp.summary())
    return spec, labels


def _fault_row(hist, labels, wall_s, solo=False):
    row = _row(hist, labels, wall_s, solo=solo)
    row["replan_rounds"] = [r.replan_round for r in hist.records]
    row["straggler_scale"] = [round(r.straggler_scale, 3)
                              for r in hist.records]
    row["p99_worst_us"] = max(row["p99_us"])  # deterministic: the CI gate's
    # cross-run regression signal for the censored fault-epoch tail
    return row


def bench_faults(fast=True):
    from repro.dist import cosim
    from repro.netsim import faults, sweep, topology

    rows = []

    # ---- acceptance row: paper-scale three_tier chaos campaign, solo so
    # the compile-reuse attribution stays clean
    topo3 = topology.three_tier()  # 320 hosts, 320 paths
    spec, labels = _campaign_scenario(topo3, "three_tier_320", "ecmp", 20,
                                      size_bytes=16e6)
    t0 = time.time()
    hist = cosim.run_cosim(**spec)
    wall = time.time() - t0
    row = _fault_row(hist, labels, wall, solo=True)
    rows.append(row)
    emit("faults_three_tier320_ecmp_ring20", wall * 1e6,
         f"conv_epochs_{row['convergence_epochs']}_replan_"
         f"{max(row['replan_rounds'])}_rebuilds_{row['rebuilds_after_first']}")

    # ---- seeded random-campaign grid through the CRASH-PROOF pool: a
    # cell that dies or hangs salvages as a poisoned record instead of
    # burning the sweep; the gate requires zero such cells
    topo2 = topology.leaf_spine(8, 12, 16, 100e9)
    if fast:
        grid = [("ecmp", 8), ("seqbalance", 8)]
        seeds = (0,)
    else:
        grid = [(s, r) for s in ("seqbalance", "ecmp", "letflow")
                for r in (8, 16, 32)]
        seeds = (0, 1)
    jobs, job_labels = [], []
    for seed in seeds:
        for scheme, ring in grid:
            camp = faults.random_campaign(topo2, seed=seed + 17, epochs=8,
                                          n_faults=3, n_ranks=ring)
            spec = dict(topo=topo2, hosts=cosim.ring_hosts(topo2, ring),
                        size_bytes=8e6, scheme=scheme, epochs=8, phi_steps=2,
                        cooldown_steps=2, n_chunks=4, seed=seed,
                        campaign=camp)
            jobs.append(spec)
            job_labels.append(dict(topo="leaf_spine_128", scheme=scheme,
                                   ring=ring, seed=seed, kill_epoch=1,
                                   campaign=camp.summary()))
    t0 = time.time()
    hists = cosim.run_cosim_grid(jobs, salvage=True)
    grid_wall = time.time() - t0
    crashed = 0
    for hist, labels in zip(hists, job_labels):
        if hist is None or getattr(hist, "failed", False):
            crashed += 1
            rows.append(dict(labels, crashed=True,
                             error=getattr(hist, "error", "worker died")))
            continue
        row = _fault_row(hist, labels, grid_wall / max(len(jobs), 1))
        rows.append(row)
        emit(f"faults_{labels['topo']}_{labels['scheme']}"
             f"_ring{labels['ring']}_s{labels['seed']}",
             grid_wall / max(len(jobs), 1) * 1e6,
             f"conv_epochs_{row['convergence_epochs']}")

    PERF["faults"] = dict(
        sweep_config=dict(devices=sweep.sweep_devices(),
                          batch_mode=sweep.batch_mode()),
        crashed_cells=crashed,
        salvage=True,
        rows=rows,
    )


# ------------------------------------------------------ degraded telemetry
def _telemetry_scenario(topo, topo_name, *, ring, size_bytes, loss, delay,
                        staleness_bound=2, blackout=None, blackout_epochs=3,
                        kill_epoch=2, recover_epoch=6, epochs=10, spine=3,
                        seed=0, channel_seed=7):
    """One killed-spine convergence run with the congestion feedback routed
    through a degraded TelemetryChannel.  ``loss=None`` is the no-channel
    legacy row (the bit-identity reference the gate pins (0, 0) against)."""
    from repro.dist import cosim
    from repro.netsim import faults

    spec = dict(
        topo=topo, hosts=cosim.ring_hosts(topo, ring), size_bytes=size_bytes,
        scheme="ecmp", epochs=epochs, phi_steps=2, n_chunks=4, seed=seed,
        faults=(cosim.kill_spine(topo, spine, epoch=kill_epoch,
                                 recover_epoch=recover_epoch),),
    )
    if loss is not None:
        spec.update(
            telemetry=faults.TelemetryChannel(
                loss=loss, delay_epochs=delay, seed=channel_seed,
                blackout=blackout),
            staleness_bound=staleness_bound,
            blackout_epochs=blackout_epochs)
    labels = dict(topo=topo_name, scheme="ecmp", ring=ring, spine=spine,
                  kill_epoch=kill_epoch, recover_epoch=recover_epoch,
                  seed=seed, loss=loss, delay=delay,
                  staleness_bound=staleness_bound if loss is not None
                  else None,
                  blackout=list(blackout) if blackout else None)
    return spec, labels


def _telemetry_row(hist, labels, wall_s):
    row = _row(hist, labels, wall_s)
    vs = row["plan_version"]
    row["version_monotone"] = bool(
        all(b > a for a, b in zip(vs, vs[1:])))
    row["plan_refused"] = int(hist.plan_refused)
    row["safe_epochs"] = [r.epoch for r in hist.records if r.safe_mode]
    row["dropped_reports"] = int(sum(
        max(r.reports_sent, 0) - max(r.reports_delivered, 0)
        for r in hist.records))
    return row


def bench_telemetry(fast=True):
    """ISSUE 7 acceptance: the control plane survives its own degradation.

      * three_tier killed-agg acceptance cells — no channel (the legacy
        reference), perfect channel (gate: p99 curves bit-identical to no
        channel), lossless 2-epoch delay, and 30 % loss + 2-epoch delay
        (gate: reconverges within +1 epoch of the lossless same-delay
        baseline, plan versions strictly monotone, zero refused newer
        plans);
      * a full telemetry BLACKOUT cell — the watchdog must flip the run
        into safe mode (no steering on stale state) and the run must
        reconverge after the channel heals (both gated);
      * the loss {0, 0.1, 0.3, 0.5} x delay {0, 1, 2} grid on the 2-tier
        fabric: convergence vs channel degradation curves (loss <= 0.3
        cells gated at lossless-same-delay + 1).
    """
    from repro.dist import cosim
    from repro.netsim import sweep, topology

    rows = []

    # ---- three_tier acceptance cells (one compile, shared by the pool)
    topo3 = topology.three_tier()  # 320 hosts, 320 paths
    cells = [
        ("none", dict(loss=None, delay=0)),
        ("perfect", dict(loss=0.0, delay=0)),
        ("delay2", dict(loss=0.0, delay=2)),
        ("loss30_delay2", dict(loss=0.3, delay=2)),
        ("blackout", dict(loss=0.0, delay=0, blackout=(0, 5),
                          blackout_epochs=2, recover_epoch=8, epochs=12)),
    ]
    jobs, job_labels, names = [], [], []
    for name, kw in cells:
        spec, labels = _telemetry_scenario(topo3, "three_tier_320", ring=20,
                                           size_bytes=16e6, **kw)
        jobs.append(spec)
        job_labels.append(labels)
        names.append(name)
    t0 = time.time()
    hists = cosim.run_cosim_grid(jobs)
    wall = time.time() - t0
    for name, hist, labels in zip(names, hists, job_labels):
        row = _telemetry_row(hist, labels, wall / len(jobs))
        row["cell"] = name
        rows.append(row)
        emit(f"telemetry_three_tier320_{name}", wall / len(jobs) * 1e6,
             f"conv_epochs_{row['convergence_epochs']}_safe_"
             f"{len(row['safe_epochs'])}_refused_{row['plan_refused']}")

    # ---- loss x delay grid on the 2-tier fabric
    topo2 = topology.leaf_spine(8, 12, 16, 100e9)
    losses = (0.0, 0.1, 0.3, 0.5)
    delays = (0, 1, 2)
    jobs, job_labels = [], []
    for loss in losses:
        for delay in delays:
            spec, labels = _telemetry_scenario(
                topo2, "leaf_spine_128", ring=8, size_bytes=8e6,
                loss=loss, delay=delay)
            jobs.append(spec)
            job_labels.append(labels)
    t0 = time.time()
    hists = cosim.run_cosim_grid(jobs)
    grid_wall = time.time() - t0
    for hist, labels in zip(hists, job_labels):
        row = _telemetry_row(hist, labels, grid_wall / len(jobs))
        row["cell"] = f"grid_l{labels['loss']}_d{labels['delay']}"
        rows.append(row)
        emit(f"telemetry_grid_l{int(labels['loss'] * 100)}_d{labels['delay']}",
             grid_wall / len(jobs) * 1e6,
             f"conv_epochs_{row['convergence_epochs']}")

    PERF["telemetry"] = dict(
        sweep_config=dict(devices=sweep.sweep_devices(),
                          batch_mode=sweep.batch_mode()),
        rows=rows,
    )
