"""Flowcell-granularity acceptance bench (DESIGN.md §17).

The reordering trade the paper's no-reordering rule avoids, measured
head-on and CI-gated by ``scripts/check_bench.py --flowcell``:

  * SeqBalance (chunk granularity, no reordering) vs flowcell spraying
    (chunks split over all active paths) vs flowlet WCMP rerouting, as
    censored-p99 grids on the symmetric fabric AND the mixed 100G/400G
    hetero fabric — the flowcell arm runs once with the reordering cost
    forced FREE (reorder=None) and once per go-back-N budget.  The
    acceptance shape: spraying beats SeqBalance ONLY in the free arm and
    loses at a strict realistic budget on the symmetric fabric (the
    paper's motivation, quantified);
  * compile-reuse: a solo co-sim with flowcells + reorder live builds all
    executables at epoch 0 and ZERO after (spray is a traced trace
    column, the budget a traced scalar operand);
  * degeneracy: flowcells=1 / reorder=0-on-unsprayed arms must match the
    classic path with stat diff EXACTLY 0 (not epsilon).

Run FIRST in its shape bucket for clean rebuild attribution — the bench
clears the sweep cache itself.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import PERF, emit


def _censored_p99(result, trace, horizon_s):
    from repro.netsim import metrics

    f, completion = metrics.fct_samples(result, trace, horizon_s=horizon_s)
    return (float(np.percentile(f, 99) * 1e6),
            float(np.percentile(f, 50) * 1e6), completion)


def _grid(topo, link_bw, *, duration_s, size, gap, budgets, fcells):
    """One fabric's arm grid: scheme baselines at chunk granularity, then
    the flowcell split at every reorder budget (None = cost-free)."""
    from repro.dist import collectives, cosim
    from repro.netsim import sweep, workloads
    from repro.netsim.engine import SimConfig

    hosts = cosim.ring_hosts(topo, 8)
    P = topo.n_paths
    plan = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    plan_fc = dataclasses.replace(plan, flowcells=fcells)
    kw = dict(link_bw=link_bw, round_gap_s=gap, seed=0, steer_paths=P)
    tr = workloads.collective_trace(plan, hosts, size, **kw)
    tr_fc = workloads.collective_trace(plan_fc, hosts, size, **kw)

    arms = [("seqbalance", "seqbalance", tr, None),
            ("ecmp", "ecmp", tr, None),
            ("flowlet_timeout", "flowlet_timeout", tr, None),
            ("flowcell_free", "ecmp", tr_fc, None)]
    for b in budgets:
        arms.append((f"flowcell_b{int(b)}", "ecmp", tr_fc, float(b)))

    rows = {}
    for name, scheme, trace, reorder in arms:
        cfg = SimConfig(scheme=scheme, duration_s=duration_s)
        res, _ = sweep.run_one(topo, cfg, trace, reorder=reorder)
        p99, p50, completion = _censored_p99(res, trace, duration_s)
        rows[name] = dict(p99_us=round(p99, 2), p50_us=round(p50, 2),
                          completion=round(completion, 4),
                          scheme=scheme, reorder_budget=reorder,
                          flowcells=fcells if trace is tr_fc else 1)
    return rows


def bench_flowcell(fast=True):
    from repro.dist import cosim
    from repro.netsim import sweep, topology, workloads
    from repro.netsim.engine import SimConfig

    duration_s = 10e-3
    size, gap = 16e6, 3e-4
    budgets = (0, 4, 16) if fast else (0, 2, 4, 8, 16, 64)
    fcells = 4

    # fabric-bound scenario: 100G hosts over a 25G fabric, so the uplinks
    # (not the NICs) decide the FCT tail the balancer is judged on
    topo_sym = topology.leaf_spine(4, 4, 4, 25e9, host_bw=100e9)
    topo_het = topology.hetero_leaf_spine(4, 4, 4, 25e9, 100e9,
                                          n_fast_spines=1, host_bw=100e9)
    sweep.clear_cache()
    t0 = time.time()
    grids = {}
    for fabric, topo in (("symmetric", topo_sym), ("hetero", topo_het)):
        grids[fabric] = _grid(topo, 25e9, duration_s=duration_s, size=size,
                              gap=gap, budgets=budgets, fcells=fcells)
    wall_grid = time.time() - t0

    sym = grids["symmetric"]
    free_wins = sym["flowcell_free"]["p99_us"] <= sym["seqbalance"]["p99_us"]
    strict = sym[f"flowcell_b{int(budgets[0])}"]
    gbn_loses = strict["p99_us"] >= sym["seqbalance"]["p99_us"]
    emit("flowcell_grid", wall_grid / max(len(sym), 1) * 1e6,
         f"sym_p99us_seq_{sym['seqbalance']['p99_us']:.0f}_free_"
         f"{sym['flowcell_free']['p99_us']:.0f}_strict_"
         f"{strict['p99_us']:.0f}_free_wins_{free_wins}"
         f"_gbn_loses_{gbn_loses}")

    # ---------------- compile reuse: solo co-sim, flowcells + budget live
    topo_c = topology.leaf_spine(4, 4, 4, 100e9)
    sweep.clear_cache()
    hist = cosim.run_cosim(
        topo_c, cosim.ring_hosts(topo_c, 8), 4e6, scheme="seqbalance",
        epochs=4 if fast else 8, phi_steps=2, n_chunks=4, seed=0,
        flowcells=fcells, reorder_budget=16.0,
        faults=(cosim.kill_spine(topo_c, 2, epoch=1, recover_epoch=3),))
    rebuilds = sum(r.new_builds for r in hist.records[1:])
    emit("flowcell_cosim_reuse", 0.0,
         f"rebuilds_after_e0_{rebuilds}_epochs_{len(hist.records)}")

    # ---------------- degeneracy: flowcells=1 and reorder-on-unsprayed
    # must match the classic path with stat diff EXACTLY 0
    from repro.dist import collectives

    plan1 = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1),
                                 flowcells=1, reorder_budget=9.0)
    plan0 = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    kw = dict(link_bw=25e9, round_gap_s=gap, seed=0,
              steer_paths=topo_sym.n_paths)
    hosts = cosim.ring_hosts(topo_sym, 8)
    tr0 = workloads.collective_trace(plan0, hosts, size, **kw)
    tr1 = workloads.collective_trace(plan1, hosts, size, **kw)
    cfg = SimConfig(scheme="seqbalance", duration_s=duration_s)
    r_base, _ = sweep.run_one(topo_sym, cfg, tr0)
    r_plan1, _ = sweep.run_one(topo_sym, cfg, tr1)
    r_zero, _ = sweep.run_one(topo_sym, cfg, tr0, reorder=0.0)
    stats = [_censored_p99(r, tr0, duration_s)
             for r in (r_base, r_plan1, r_zero)]
    diff = max(abs(a - b) for s in stats[1:]
               for a, b in zip(stats[0], s))
    emit("flowcell_degenerate", 0.0, f"max_stat_diff_{diff}")

    PERF["flowcell"] = dict(
        fast=fast, flowcells=fcells, budgets=[float(b) for b in budgets],
        duration_s=duration_s, size_bytes=size, round_gap_s=gap,
        grids=grids,
        free_beats_seqbalance=bool(free_wins),
        gbn_loses_on_symmetric=bool(gbn_loses),
        rebuilds_after_first=int(rebuilds),
        degenerate_stat_diff=float(diff),
        wall_s=round(wall_grid, 2),
    )
