"""Kernel micro-benches: wall time of the jnp reference path on CPU (the
Pallas kernels target TPU; interpret mode is a correctness harness, so the
derived column reports ref-path throughput + kernel/ref agreement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import flash_attention as fa, linkload as ll, ref


def bench_kernels(fast=True):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, hd = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    f(q, k, v).block_until_ready()
    _, us = timed(lambda: f(q, k, v).block_until_ready(), repeat=5)
    flops = 4 * B * H * S * S * hd / 2
    o1 = fa.flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    err = float(jnp.max(jnp.abs(o1 - f(q, k, v))))
    emit("kernel_flash_attention_ref", us,
         f"{flops/us/1e3:.1f}GFLOPs_kernel_maxerr_{err:.1e}")

    n, L = 8192, 512
    lid = jax.random.randint(ks[0], (n, 6), -1, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[1], (n,)) * 1e9
    queue = jnp.zeros((L,))
    cap = jnp.full((L,), 1e11)
    g = jax.jit(lambda: ref.linkload_ref(lid, rates, L, 400e3, 1600e3, 0.2, queue, cap, 1e-5))
    g()[0].block_until_ready()
    _, us = timed(lambda: g()[0].block_until_ready(), repeat=10)
    l1, _, _ = ll.linkload(lid, rates, queue, cap, n_links=L, interpret=True)
    err = float(jnp.max(jnp.abs(l1 - g()[0])))
    emit("kernel_linkload_ref", us, f"{n*6/us:.0f}Mupdates/s_kernel_maxerr_{err:.1e}")
