"""Observability-plane acceptance bench (DESIGN.md §16).

Two claims, both CI-gated by ``scripts/check_bench.py --obs``:

  * recording is (almost) free — the traced ring buffer adds <= 5% to a
    warm per-dispatch wall clock on the sparse collective workload
    (measured interleaved, min-of-iters, exactly like the adaptive-dt
    bench: contention spikes hit whichever variant is running);
  * recording never recompiles — one extra executable at epoch 0 per
    shape bucket, ZERO cache builds after, demonstrated on the paper's
    killed-aggregation-spine co-sim (three_tier, 320 hosts, 20-member
    ring): every epoch lands in the flight log, the perfetto export
    covers the whole campaign, and ``new_builds`` past epoch 0 sums to 0.

Run FIRST in its shape bucket for clean rebuild attribution — the bench
clears the sweep cache itself.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import PERF, emit


def bench_obs(fast=True):
    from benchmarks.paper_benches import _collective_setup
    from repro import obs
    from repro.dist import cosim
    from repro.netsim import sweep, topology

    # ---------------- recording overhead: warm, interleaved, min-of-iters
    topo, cfg, trc = _collective_setup()
    rec = obs.RecordSpec(ring_chunks=64)
    iters = 3 if fast else 5

    def wall_one(record):
        sweep.run_one(topo, cfg, trc, record=record)  # compile + warm
        best = float("inf")
        for _ in range(iters):
            t0 = time.time()
            sweep.run_one(topo, cfg, trc, record=record)
            best = min(best, time.time() - t0)
        return best

    sweep.clear_cache()
    wall_one(None)
    wall_one(rec)  # both executables warm before any measurement
    builds_warm = sweep.cache_stats()["builds"]
    wall_off = wall_on = float("inf")
    for _ in range(2):
        wall_off = min(wall_off, wall_one(None))
        wall_on = min(wall_on, wall_one(rec))
    rebuilds = sweep.cache_stats()["builds"] - builds_warm
    overhead_pct = (wall_on / wall_off - 1.0) * 100.0
    emit("obs_record_overhead", wall_on * 1e6,
         f"{overhead_pct:+.2f}%_vs_unrecorded_rebuilds_{rebuilds}")

    # ------------- killed-agg-spine co-sim: flight log + zero rebuilds
    topo3 = topology.three_tier()
    ring = cosim.ring_hosts(topo3, 20)
    epochs = 10
    fd, flight = tempfile.mkstemp(suffix=".jsonl", prefix="bench_flight_")
    os.close(fd)
    try:
        sweep.clear_cache()
        t0 = time.time()
        hist = cosim.run_cosim(
            topo3, ring, 16e6, scheme="ecmp", epochs=epochs, phi_steps=2,
            n_chunks=4, seed=0,
            faults=(cosim.kill_spine(topo3, 3, epoch=2, recover_epoch=6),),
            record=rec, flight=flight)
        wall = time.time() - t0
        rebuilds_cosim = sum(r.new_builds for r in hist.records[1:])
        header, events = obs.read_flight(flight)
        ep_logged = [r for r in events if r["kind"] == "epoch"]
        insim_all = all(r.get("insim") for r in ep_logged)
        from repro.obs import trace_export
        trace = trace_export.chrome_trace(header, events)
        n_tev = len(trace["traceEvents"])
        from repro.obs.features import epoch_matrix
        mat = epoch_matrix((header, events))["matrix"]
    finally:
        os.unlink(flight)
    conv = hist.convergence_epoch(2)
    emit("obs_cosim_flight", wall / epochs * 1e6,
         f"epochs_{len(ep_logged)}of{epochs}_rebuilds_after_e0_"
         f"{rebuilds_cosim}_trace_events_{n_tev}_conv_{conv}")

    PERF["obs"] = dict(
        fast=fast,
        ring_chunks=rec.ring_chunks,
        wall_off_s=round(wall_off, 4), wall_on_s=round(wall_on, 4),
        overhead_pct=round(overhead_pct, 3),
        rebuilds_warm=int(rebuilds),
        cosim=dict(
            epochs=epochs, flight_epochs=len(ep_logged),
            rebuilds_after_epoch0=int(rebuilds_cosim),
            insim_every_epoch=bool(insim_all),
            trace_events=int(n_tev),
            matrix_shape=list(mat.shape),
            convergence_epoch=conv,
            wall_s=round(wall, 2)),
        # gate floors (scripts/check_bench.py --obs): recording must stay
        # within max_overhead_pct of the unrecorded twin and must never
        # build an executable after its first dispatch of a shape
        floors=dict(max_overhead_pct=5.0),
    )
