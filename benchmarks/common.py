"""Shared benchmark helpers: timing + CSV emission + sim runners."""
from __future__ import annotations

import time

import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6


def run_sim(topo, trace, scheme: str, duration_s: float, **cfg_kw):
    from repro.netsim import engine, metrics

    cfg = engine.SimConfig(scheme=scheme, duration_s=duration_s, **cfg_kw)
    t0 = time.time()
    st, outs = engine.simulate(topo, cfg, trace)
    st.finish.block_until_ready()
    wall_us = (time.time() - t0) * 1e6
    return st, outs, wall_us


def fct(st, trace, topo, host_bw):
    from repro.netsim import metrics

    return metrics.fct_stats(st, trace, topo, host_bw)
