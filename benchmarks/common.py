"""Shared benchmark helpers: timing + CSV emission + sim runners.

``run_sim`` routes through the active-window compact engine by default
(netsim/sweep.py); pass ``dense=True`` (or set REPRO_DENSE_ENGINE=1) for the
dense oracle.  ``run_sim_batch`` runs a list of traces as ONE vmapped
computation per (scheme, topology) — the fast path for the Fig. 12-14
sweeps.  ``PERF`` collects machine-readable perf records that
benchmarks/run.py dumps to BENCH_netsim.json.
"""
from __future__ import annotations

import os
import time

import numpy as np

ROWS = []
PERF = {}


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6


def run_sim(topo, trace, scheme: str, duration_s: float, dense: bool = False, **cfg_kw):
    """One simulation; returns (state_like, per-step outputs, wall_us).

    The returned state duck-types the fields the metrics layer reads
    (``finish``, ``cnp_pkts``) for both engines."""
    from repro.netsim import engine, sweep

    cfg = engine.SimConfig(scheme=scheme, duration_s=duration_s, **cfg_kw)
    t0 = time.time()
    if dense or os.environ.get("REPRO_DENSE_ENGINE"):
        st, outs = engine.simulate(topo, cfg, trace)
        st.finish.block_until_ready()
    else:
        st, outs = sweep.run_one(topo, cfg, trace)
    wall_us = (time.time() - t0) * 1e6
    return st, outs, wall_us


def run_sim_batch(topo, traces, scheme: str, duration_s: float, **cfg_kw):
    """All traces under one (scheme, topo) static pair as a single vmapped
    run.  Returns (list[(state_like, outs)], wall_us)."""
    from repro.netsim import engine, sweep

    cfg = engine.SimConfig(scheme=scheme, duration_s=duration_s, **cfg_kw)
    t0 = time.time()
    results, outs_list = sweep.run_batch(topo, cfg, traces)
    wall_us = (time.time() - t0) * 1e6
    return list(zip(results, outs_list)), wall_us


def run_sim_jobs(topo, traces, schemes, duration_s: float, **cfg_kw):
    """One sweep job per scheme, run concurrently (netsim/sweep.run_jobs).
    Returns ({scheme: [(state_like, outs), ...]}, wall_us)."""
    from repro.netsim import engine, sweep

    jobs = [
        (topo, engine.SimConfig(scheme=s, duration_s=duration_s, **cfg_kw), traces)
        for s in schemes
    ]
    t0 = time.time()
    out = sweep.run_jobs(jobs)
    wall_us = (time.time() - t0) * 1e6
    return {s: list(zip(r, o)) for s, (r, o) in zip(schemes, out)}, wall_us


def fct(st, trace, topo, host_bw):
    from repro.netsim import metrics

    return metrics.fct_stats(st, trace, topo, host_bw)
