"""One benchmark per paper table/figure (DESIGN.md §7 index).

Each function prints ``name,us_per_call,derived`` CSV rows.  ``fast=True``
(default) runs reduced durations/scales that preserve the paper's trends;
``--full`` in run.py uses the paper-scale parameters.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    PERF, emit, fct, run_sim, run_sim_batch, run_sim_jobs, timed,
)


# ------------------------------------------------------------- Table I
def bench_table1_gbn(fast=True):
    import jax.numpy as jnp
    from repro.core import gbn

    sizes = jnp.array([64e3, 1e6], jnp.float32)
    (ratios, us) = timed(lambda: np.asarray(gbn.table1_inflation(sizes)), repeat=10)
    emit("table1_gbn_64KB_avg_inflation", us, f"{ratios[0]:.2f}x_paper_5.77x")
    emit("table1_gbn_1MB_avg_inflation", us, f"{ratios[1]:.2f}x_paper_3.01x")
    emit("table1_min_threefold", us, f"min_inflation_{ratios.min():.2f}_paper_claims_>=3x")


# ------------------------------------------------------------- Fig. 1
def bench_fig1_flowlet(fast=True):
    """Flowlet sizes under inactivity thresholds: TCP (bursty, ack-clocked)
    vs RDMA (continuous line-rate).  Packet-trace synthesis + gap scan."""
    rng = np.random.default_rng(0)
    mtu = 1500.0
    line = 40e9

    def flowlet_sizes(inter_arrival_s, thresh):
        gaps = inter_arrival_s > thresh
        sizes, cur = [], 0.0
        for g in gaps:
            cur += mtu
            if g:
                sizes.append(cur)
                cur = 0.0
        if cur:
            sizes.append(cur)
        return np.array(sizes)

    n = 40000 if fast else 400000
    # TCP: cwnd-sized bursts every RTT (100us), ack-clocked spacing inside
    rtt = 100e-6
    cwnd = 64
    intra = mtu * 8 / line
    tcp_ia = np.tile(np.r_[np.full(cwnd - 1, intra), rtt - (cwnd - 1) * intra], n // cwnd)
    # RDMA: continuous line-rate stream with tiny jitter
    rdma_ia = np.full(n, intra) * rng.uniform(0.9, 1.1, n)

    def med(ia, th):
        s = flowlet_sizes(ia, th)
        return float(np.median(s)) if len(s) else float(ia.size * mtu)

    for th_us in (10, 100, 500):
        th = th_us * 1e-6
        (m_tcp, us) = timed(med, tcp_ia, th)
        m_rdma = med(rdma_ia, th)
        emit(f"fig1_flowlet_tcp_{th_us}us", us, f"median_{m_tcp/1e3:.1f}KB")
        emit(f"fig1_flowlet_rdma_{th_us}us", us,
             f"median_{m_rdma/1e6:.1f}MB_ratio_{m_rdma/max(m_tcp,1):.0f}x")


# ---------------------------------------------------------- Fig. 6 / 7
def bench_fig6_fig7_nsweep(fast=True):
    from repro.netsim import metrics, topology, workloads

    topo = topology.leaf_spine(4, 8, 8, 100e9)
    dur = 5e-3 if fast else 20e-3
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="fixed:10e6", load=0.6, duration_s=dur, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=3, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=4 * 8 * 100e9,
    ))
    base = None
    for n in (1, 2, 4, 6):
        st, outs, us = run_sim(topo, trace, "seqbalance", dur * 4, n_sub=n)
        s = fct(st, trace, topo, 100e9)
        imb = float(np.median(metrics.throughput_imbalance(outs)))
        if n == 2:
            base = s["avg_slowdown"]
        rel = "" if base is None else f"_vs_N2_{(1 - s['avg_slowdown']/base)*100:+.1f}%"
        emit(f"fig6_fct_N{n}", us,
             f"avg_slow_{s['avg_slowdown']:.3f}_p99_{s['p99_slowdown']:.2f}{rel}")
        emit(f"fig7_imbalance_N{n}", us, f"median_imbalance_{imb:.3f}")


# ---------------------------------------------------------- Fig. 10/11
def _pairs_trace(n_qp=4, size=1e12, starts=(0.0, 5e-3, 10e-3)):
    from repro.netsim import workloads

    pairs, st = [], []
    for i, t0 in enumerate(starts):
        for _ in range(n_qp):
            pairs.append((i, 3 + i))
            st.append(t0)
    return workloads.permanent_senders_trace(pairs, st, size / n_qp)


def _dc40():
    from repro.netsim.dcqcn import DCQCNParams

    return DCQCNParams(kmin_bytes=160e3, kmax_bytes=520e3, r_ai=400e6, min_rate=400e6)


def bench_fig10_symmetric(fast=True):
    from repro.netsim import topology

    topo = topology.testbed_symmetric()
    for scheme in ("ecmp", "seqbalance"):
        st, outs, us = run_sim(topo, _pairs_trace(), scheme, 15e-3, dcqcn=_dc40())
        up = np.asarray(outs.uplink_load)[:, 0, :]
        late = up[1000:].mean(0) / 1e9
        tot = late.sum()
        spread = late.max() - late.min()
        emit(f"fig10_sym_{scheme}", us,
             f"total_{tot:.1f}Gbps_perpath_{'/'.join(f'{v:.0f}' for v in late)}_spread_{spread:.1f}")


def bench_fig11_asymmetric(fast=True):
    from repro.netsim import topology

    topo = topology.testbed_asymmetric()
    res = {}
    for scheme in ("ecmp", "seqbalance"):
        st, outs, us = run_sim(topo, _pairs_trace(), scheme, 15e-3, dcqcn=_dc40())
        up = np.asarray(outs.uplink_load)[:, 0, :]
        late = up[1000:].mean(0) / 1e9
        res[scheme] = late
        emit(f"fig11_asym_{scheme}", us,
             f"total_{late.sum():.1f}Gbps_fatpath_{late[2]:.1f}Gbps")
    fat_gain = res["seqbalance"][2] / max(res["ecmp"][2], 1e-9)
    emit("fig11_asym_fatpath_gain", 0.0, f"seqbalance_uses_80G_path_{fat_gain:.2f}x_of_ecmp")


# ------------------------------------------------------------- Table II
def bench_table2_overhead(fast=True):
    from repro.netsim import metrics, topology, workloads

    topo = topology.testbed_symmetric()
    for nsend, label in ((1, 25), (2, 50), (3, 75)):
        pairs = [(i, 3 + i) for i in range(nsend) for _ in range(4)]
        trace = workloads.permanent_senders_trace(pairs, [0.0] * len(pairs), 2.5e8)
        st, outs, us = run_sim(topo, trace, "seqbalance", 10e-3, dcqcn=_dc40())
        bw = metrics.congestion_packet_bandwidth(st, 10e-3)
        data_bw = np.asarray(outs.goodput_total).mean()
        emit(f"table2_load{label}", us,
             f"cong_pkt_{bw/1e3:.2f}Kbps_data_{data_bw/1e9:.1f}Gbps_paper_0/4Kbps/0.05Gbps")


# ---------------------------------------------------- Fig. 12/13 (2-tier)
def _poisson(topo, wl, load, dur, seed=1):
    from repro.netsim import workloads

    fabric = topo.n_leaf * topo.n_paths * 100e9
    return workloads.poisson_trace(workloads.TraceConfig(
        workload=wl, load=load, duration_s=dur, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=seed, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=fabric,
    ))


def fig12_cases(fast=True):
    loads = (0.5, 0.8) if fast else (0.3, 0.4, 0.5, 0.6, 0.7, 0.8)
    return [(wl, load) for wl in ("alistorage", "websearch") for load in loads]


def bench_fig12_fct_2tier(fast=True):
    from repro.netsim import topology

    topo = topology.sim_2tier()
    arr = 2.5e-3 if fast else 10e-3
    cases = fig12_cases(fast)
    traces = {c: _poisson(topo, c[0], c[1], arr) for c in cases}
    # drill first: its spill-retry makes it the longest job by far, so it
    # anchors one worker while the cheap schemes pack onto the others
    schemes = ("drill", "ecmp", "seqbalance", "letflow", "conga")
    # one vmapped sweep job per scheme over every (workload, load) trace,
    # all five jobs running concurrently; FCT-only consumers sample the
    # uplink trace at the imbalance stride instead of materializing [T,L,S]
    results, us = run_sim_jobs(topo, [traces[c] for c in cases], schemes, arr * 4,
                               uplink_sample_every=10)
    stats = {}
    for scheme in schemes:
        for c, (st, outs) in zip(cases, results[scheme]):
            stats[(scheme, c)] = fct(st, traces[c], topo, 100e9)
        for c in cases:
            s = stats[(scheme, c)]
            emit(f"fig12_{c[0]}_{int(c[1]*100)}_{scheme}",
                 us / (len(cases) * len(schemes)),
                 f"avg_slow_{s['avg_slowdown']:.2f}_p99_{s['p99_slowdown']:.1f}_comp_{s['completion_rate']:.3f}")
    for c in cases:
        g = (1 - stats[("seqbalance", c)]["p99_slowdown"]
             / stats[("ecmp", c)]["p99_slowdown"]) * 100
        emit(f"fig12_{c[0]}_{int(c[1]*100)}_gain", 0.0, f"seq_vs_ecmp_p99_{g:+.1f}%")


def bench_fig13_imbalance(fast=True):
    from repro.netsim import metrics, topology

    topo = topology.sim_2tier()
    arr = 2e-3 if fast else 10e-3
    wls = ("alistorage", "websearch")
    schemes = ("drill", "ecmp", "seqbalance", "conga")  # longest job first
    traces = [_poisson(topo, wl, 0.8, arr) for wl in wls]
    results, us = run_sim_jobs(topo, traces, schemes, arr * 2,
                               uplink_sample_every=10)
    for scheme in schemes:
        for wl, (st, outs) in zip(wls, results[scheme]):
            imb = metrics.throughput_imbalance(outs, trace_stride=10)
            med = float(np.median(imb)) if len(imb) else -1
            p90 = float(np.percentile(imb, 90)) if len(imb) else -1
            emit(f"fig13_{wl}_{scheme}", us / (len(wls) * len(schemes)),
                 f"imb_median_{med:.3f}_p90_{p90:.3f}")


# ------------------------------------------------------- Fig. 14 (3-tier)
def bench_fig14_fct_3tier(fast=True):
    from repro.netsim import topology, workloads

    if fast:
        topo = topology.three_tier(n_tor=4, n_agg=4, n_core=2, hosts_per_tor=3,
                                   bw_tor_agg=400e9, bw_agg_core=100e9)
    else:
        topo = topology.three_tier()  # paper scale: 20/20/16, 320 hosts
    arr = 1.5e-3 if fast else 8e-3
    fabric = topo.n_leaf * 4 * 100e9
    wls = ("alistorage", "websearch")
    traces = [workloads.poisson_trace(workloads.TraceConfig(
        workload=wl, load=0.6, duration_s=arr, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=2, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=fabric,
    )) for wl in wls]
    schemes = ("ecmp", "letflow", "seqbalance")
    results, us = run_sim_jobs(topo, traces, schemes, arr * 4)
    stats = {}
    for scheme in schemes:
        for wl, trace, (st, outs) in zip(wls, traces, results[scheme]):
            s = fct(st, trace, topo, 100e9)
            stats[(scheme, wl)] = s
            emit(f"fig14_{wl}_{scheme}", us / (len(wls) * len(schemes)),
                 f"avg_slow_{s['avg_slowdown']:.2f}_p99_{s['p99_slowdown']:.1f}")
    for wl in wls:
        g = (1 - stats[("seqbalance", wl)]["p99_slowdown"]
             / stats[("ecmp", wl)]["p99_slowdown"]) * 100
        emit(f"fig14_{wl}_gain", 0.0, f"seq_vs_ecmp_p99_{g:+.1f}%")


# ------------------------------------------------- §Perf (DESIGN.md §9)
def bench_netsim_speedup(fast=True):
    """Acceptance bench: the Fig. 12 fast sweep on the active-window
    vmapped engine vs the dense oracle — wall clock, per-step cost, and the
    FCT-slowdown agreement between the two.  Records PERF["fig12_sweep"]
    for BENCH_netsim.json."""
    import time

    from repro.netsim import sweep, topology

    topo = topology.sim_2tier()
    arr = 2.5e-3 if fast else 10e-3
    dur = arr * 4
    cases = fig12_cases(fast)
    schemes = ("drill", "ecmp", "seqbalance", "letflow", "conga")  # longest first
    traces = {c: _poisson(topo, c[0], c[1], arr) for c in cases}
    n_steps = int(round(dur / 10e-6))
    n_sims = len(cases) * len(schemes)

    sweep.clear_cache()  # time cold compiles like the dense path pays them
    t0 = time.time()
    compact_stats, spill = {}, 0
    results, _ = run_sim_jobs(topo, [traces[c] for c in cases], schemes, dur,
                              uplink_sample_every=10)
    for scheme in schemes:
        for c, (st, _) in zip(cases, results[scheme]):
            compact_stats[(scheme, c)] = fct(st, traces[c], topo, 100e9)
            spill = max(spill, st.spill_steps)
    compact_wall = time.time() - t0

    t0 = time.time()
    dense_stats = {}
    for scheme in schemes:
        for c in cases:
            st, _, _ = run_sim(topo, traces[c], scheme, dur, dense=True)
            dense_stats[(scheme, c)] = fct(st, traces[c], topo, 100e9)
    dense_wall = time.time() - t0

    diffs = {}
    for key in compact_stats:
        for stat in ("avg_slowdown", "p99_slowdown"):
            d = abs(compact_stats[key][stat] / dense_stats[key][stat] - 1) * 100
            diffs[f"{key[0]}_{key[1][0]}_{int(key[1][1]*100)}_{stat}"] = d
    max_diff = max(diffs.values())
    speedup = dense_wall / compact_wall
    emit("netsim_sweep_compact", compact_wall * 1e6 / n_sims,
         f"wall_{compact_wall:.1f}s_{n_sims}sims_per_step_us_{compact_wall*1e6/(n_sims*n_steps):.1f}")
    emit("netsim_sweep_dense", dense_wall * 1e6 / n_sims,
         f"wall_{dense_wall:.1f}s_per_step_us_{dense_wall*1e6/(n_sims*n_steps):.1f}")
    emit("netsim_sweep_speedup", 0.0,
         f"{speedup:.1f}x_max_stat_diff_{max_diff:.3f}%_spill_{spill}")
    PERF["fig12_sweep"] = dict(
        fast=fast, n_sims=n_sims, n_steps=n_steps,
        compact_wall_s=round(compact_wall, 2), dense_wall_s=round(dense_wall, 2),
        speedup=round(speedup, 2),
        per_step_us_compact=round(compact_wall * 1e6 / (n_sims * n_steps), 2),
        per_step_us_dense=round(dense_wall * 1e6 / (n_sims * n_steps), 2),
        max_stat_diff_pct=round(max_diff, 4), spill_steps=int(spill),
        stat_diff_pct={k: round(v, 4) for k, v in diffs.items()},
    )
    # reproducibility: how the sweep was dispatched on this machine
    from repro.netsim import dataplane

    PERF["sweep_config"] = dict(
        workers=sweep.default_workers(len(schemes)),
        dataplane_backend=dataplane.resolve_backend("auto"),
        devices=sweep.sweep_devices(),
        # persistent XLA compile cache: the recorded sweep is warm from the
        # second process on (production sweeps relaunch identical programs)
        compile_cache=sweep.enable_compile_cache() or "disabled",
    )


# ------------------------------------------- adaptive dt (DESIGN.md §15)
def _collective_setup():
    """The sparse AI-training workload the adaptive engine targets: a
    ring all-reduce with 800 µs compute gaps between rounds — most chunk
    boundaries are quiescent (flows done, queues drained, next round's
    arrival still in the future)."""
    from repro.dist import collectives, cosim
    from repro.netsim import topology, workloads
    from repro.netsim.engine import SimConfig

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    hosts = cosim.ring_hosts(topo, 8)
    plan = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    trace = workloads.collective_trace(plan, hosts, 4e6, link_bw=100e9,
                                       round_gap_s=800e-6, seed=0,
                                       steer_paths=topo.n_paths)
    cfg = SimConfig(scheme="seqbalance", duration_s=14e-3,
                    uplink_sample_every=10)
    return topo, cfg, trace


def bench_adaptive_dt(fast=True):
    """Acceptance bench for the event-driven adaptive-dt engine
    (DESIGN.md §15).  Two workload regimes, both adaptive-vs-fixed-dt on
    the SAME compact engine (warm executables — this isolates the step
    loop, not compile time):

      * sparse collective trace — rounds separated by compute gaps; the
        quiescence fast-forward must cover the gaps (>= 2x wall clock);
      * the Fig. 12 fast sweep — loaded Poisson traffic where every chunk
        contains arrivals or finishes, so nothing CAN fast-forward; the
        predicate short-circuit must keep adaptive at parity (the floor
        guards the overhead, not a win).

    Also records the adaptive-vs-fixed FCT stat divergence (tolerance
    model: <= 0.01 %) and the executable-reuse contract (zero cache builds
    after the first adaptive dispatch of each shape).  The recorded
    ``floors`` are what scripts/check_bench.py --adaptive gates future
    runs against."""
    import dataclasses
    import time

    from repro.netsim import sweep

    topoL, cfg_f, trc = _collective_setup()
    cfg_a = dataclasses.replace(cfg_f, adaptive=True)
    iters = 3 if fast else 5

    def wall_one(topo, c, tr):
        res, _ = sweep.run_one(topo, c, tr)  # compile + warm
        t0 = time.time()
        for _ in range(iters):
            res, _ = sweep.run_one(topo, c, tr)
        return (time.time() - t0) / iters, res

    sweep.clear_cache()
    wall_f, res_f = wall_one(topoL, cfg_f, trc)
    wall_a, res_a = wall_one(topoL, cfg_a, trc)
    builds_warm = sweep.cache_stats()["builds"]
    sweep.run_one(topoL, cfg_a, trc)
    rebuilds = sweep.cache_stats()["builds"] - builds_warm

    n_steps = int(round(cfg_f.duration_s / cfg_f.dt))
    stats_f = fct(res_f, trc, topoL, 100e9)
    stats_a = fct(res_a, trc, topoL, 100e9)
    col_diff = max(
        abs(stats_a[s] / stats_f[s] - 1) * 100
        for s in ("avg_slowdown", "p99_slowdown"))
    col_speedup = wall_f / wall_a
    ff = int(res_a.ff_steps)
    emit("adaptive_collective_speedup", wall_a * 1e6,
         f"{col_speedup:.2f}x_ff_{ff}of{n_steps}_stat_diff_{col_diff:.4f}%")

    # Fig. 12 fast sweep, warm-vs-warm (the fixed-dt cold-compile cost is
    # already recorded in PERF["fig12_sweep"])
    from repro.netsim import topology

    topo2 = topology.sim_2tier()
    arr = 2.5e-3 if fast else 10e-3
    dur = arr * 4
    cases = fig12_cases(fast)
    schemes = ("drill", "ecmp", "seqbalance", "letflow", "conga")
    traces = {c: _poisson(topo2, c[0], c[1], arr) for c in cases}

    def sweep_once(**cfg_kw):
        t0 = time.time()
        results, _ = run_sim_jobs(topo2, [traces[c] for c in cases], schemes,
                                  dur, uplink_sample_every=10, **cfg_kw)
        wall = time.time() - t0
        stats, ff_total = {}, 0
        for scheme in schemes:
            for c, (st, _) in zip(cases, results[scheme]):
                stats[(scheme, c)] = fct(st, traces[c], topo2, 100e9)
                ff_total += int(getattr(st, "ff_steps", 0))
        return wall, stats, ff_total

    # warm both variants, then interleave and keep the per-variant minimum
    # — worker-thread contention spikes hit whichever sweep is running,
    # so back-to-back single measurements systematically smear the ratio
    sweep_once()
    sweep_once(adaptive=True)
    fig_wall_f, fig_wall_a = float("inf"), float("inf")
    for _ in range(2):
        w, fig_stats_f, _ = sweep_once()
        fig_wall_f = min(fig_wall_f, w)
        w, fig_stats_a, fig_ff = sweep_once(adaptive=True)
        fig_wall_a = min(fig_wall_a, w)
    fig_diff = max(
        abs(fig_stats_a[k][s] / fig_stats_f[k][s] - 1) * 100
        for k in fig_stats_f for s in ("avg_slowdown", "p99_slowdown"))
    fig_speedup = fig_wall_f / fig_wall_a
    emit("adaptive_fig12_sweep", fig_wall_a * 1e6 / (len(cases) * len(schemes)),
         f"{fig_speedup:.2f}x_vs_fixed_ff_{fig_ff}_stat_diff_{fig_diff:.4f}%")
    emit("adaptive_rebuilds_after_first", 0.0, f"{rebuilds}_new_executables")

    max_diff = max(col_diff, fig_diff)
    PERF["adaptive_dt"] = dict(
        fast=fast,
        collective=dict(
            fixed_wall_s=round(wall_f, 3), adaptive_wall_s=round(wall_a, 3),
            speedup=round(col_speedup, 2), ff_steps=ff, n_steps=n_steps,
            ff_fraction=round(ff / n_steps, 3),
            max_stat_diff_pct=round(col_diff, 4)),
        fig12=dict(
            fixed_wall_s=round(fig_wall_f, 2),
            adaptive_wall_s=round(fig_wall_a, 2),
            speedup=round(fig_speedup, 2), ff_steps=fig_ff,
            max_stat_diff_pct=round(fig_diff, 4)),
        max_stat_diff_pct=round(max_diff, 4),
        rebuilds_after_first=int(rebuilds),
        # gate floors (scripts/check_bench.py --adaptive): the collective
        # win is the acceptance bar; the fig12 floor guards predicate
        # overhead on event-dense traffic, where ff_steps == 0 by design
        # (every chunk has arrivals/finishes — there is nothing to skip,
        # so parity IS the win; see DESIGN.md §15)
        floors=dict(collective_speedup=2.0, fig12_speedup=0.85),
    )


# ------------------------------------------- --profile (run.py flag)
def bench_profile_phases(fast=True, schemes=("seqbalance", "ecmp")):
    """Per-phase step-cost breakdown of the compact engine (admit /
    cascade / dcqcn / finish) on the fig12 fast setup, so perf PRs can
    attribute wins.  Not part of ALL — enabled by ``run.py --profile``."""
    from repro.netsim import profile, topology
    from repro.netsim.engine import SimConfig

    topo = topology.sim_2tier()
    arr = 2.5e-3 if fast else 10e-3
    trace = _poisson(topo, "alistorage", 0.8, arr)
    record = {}
    for scheme in schemes:
        cfg = SimConfig(scheme=scheme, duration_s=arr * 4)
        times = profile.profile_phases(topo, cfg, trace)
        # TimeUs phases carry the full sample distribution: store
        # {min_us, mean_us, std_us, iters} per phase (flight-log schema),
        # plain floats/ints (phase_sum, window_slots) stay scalar
        record[scheme] = {
            k: v.stats() if isinstance(v, profile.TimeUs)
            else (round(v, 2) if isinstance(v, float) else v)
            for k, v in times.items()}
        for phase in ("admit", "cascade", "dcqcn", "finish"):
            emit(f"profile_{scheme}_{phase}", times[phase],
                 f"{times[phase]/max(times['phase_sum'],1e-9)*100:.0f}%_of_phase_sum")
        emit(f"profile_{scheme}_step_fused", times["step_fused"],
             f"phase_sum_{times['phase_sum']:.1f}us_W_{times['window_slots']}")

    # quiescence occupancy (DESIGN.md §15): replay the fixed-dt oracle and
    # record which chunk boundaries the adaptive engine would fast-forward
    # — the sparse collective trace (where the win lives) and the dense
    # fig12 trace (where the occupancy shows why there is none)
    topoL, cfgL, trcL = _collective_setup()
    for name, (t_, c_, tr_) in (
            ("collective", (topoL, cfgL, trcL)),
            ("fig12_ali80", (topo, SimConfig(scheme="seqbalance",
                                             duration_s=arr * 4,
                                             uplink_sample_every=10), trace))):
        q = profile.quiescence_profile(t_, c_, tr_)
        hist = "/".join(f"{k}x{v}" for k, v in sorted(q["macro_hist"].items()))
        emit(f"profile_quiescence_{name}", q["predicate_us"],
             f"ff_fraction_{q['ff_fraction']:.3f}_macro_hist_{hist or 'none'}"
             f"_K_{q['chunk_steps']}")
        pred = q["predicate_us"]
        record[f"quiescence_{name}"] = dict(
            ff_fraction=round(q["ff_fraction"], 4),
            predicate_us=pred.stats() if isinstance(pred, profile.TimeUs)
            else round(pred, 2),
            macro_hist={str(k): v for k, v in sorted(q["macro_hist"].items())},
            chunk_steps=q["chunk_steps"], n_chunks=q["n_chunks"])
    PERF["profile"] = record


ALL = [
    bench_table1_gbn,
    bench_fig1_flowlet,
    bench_fig6_fig7_nsweep,
    bench_fig10_symmetric,
    bench_fig11_asymmetric,
    bench_table2_overhead,
    bench_fig12_fct_2tier,
    bench_fig13_imbalance,
    bench_fig14_fct_3tier,
    bench_netsim_speedup,
    bench_adaptive_dt,
]
