"""Roofline analysis (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape), single-pod 16x16 mesh (256 chips):

  compute_term    = FLOPs / (256 * 197e12)
  memory_term     = HBM_bytes / (256 * 819e9)
  collective_term = collective_bytes / (256 * 50e9)

Numerator sources — and an honest methodological note: the container's CPU
XLA backend reports cost_analysis for a lax.scan'd (while-loop) program
with the body counted ONCE and no TPU-style fusion, so its absolute
flops/bytes are not meaningful for scanned models (verified by depth
sweeps: flops grow ~0.2%/layer).  We therefore use ANALYTIC numerators
(the standard MFU accounting: 6*N_active*D train / 2*N_active*D decode +
attention terms; explicit per-step parameter/optimizer/activation/cache
traffic; ring-collective byte formulas matched against the top-level HLO
collective ops, which ARE reliably visible).  The compiled artifact still
supplies what only it can prove: the cell compiles under the production
sharding, per-device peak memory (memory_analysis), and the collective
schedule (op mix parsed from partitioned HLO).
"""
from __future__ import annotations

import json
import os

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip (v5e-class)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link
CHIPS = 256

from repro.configs import registry  # noqa: E402


def _params(arch: str):
    import jax
    import jax.numpy as jnp
    from repro.models import model

    cfg = registry.get_config(arch)
    tree = jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = sum(int(np.prod(l.shape)) for _, l in flat)
    expert = sum(int(np.prod(l.shape)) for p, l in flat if "we_" in jax.tree_util.keystr(p))
    active = total
    if cfg.moe.n_experts:
        active = total - expert + int(expert * cfg.moe.top_k / cfg.moe.n_experts)
    return cfg, float(total), float(active)


def _attn_flops(cfg, S, B, decode: bool) -> float:
    """Global attention score+value FLOPs (the part 6ND misses)."""
    prelude, sb, n_super, trailing = __import__(
        "repro.models.transformer", fromlist=["block_program"]
    ).block_program(cfg)
    units = list(sb) * n_super + list(prelude) + list(trailing)
    f = 0.0
    for u in units:
        if u.kind != "attn":
            continue
        kv = min(S, u.window) if u.window else S
        if decode:
            f += 4.0 * B * cfg.n_heads * kv * cfg.hd
        else:
            f += 4.0 * B * cfg.n_heads * S * kv * cfg.hd * (0.5 if u.causal else 1.0)
    return f


def _cache_bytes(arch: str, shape) -> float:
    import jax
    from repro.models import model

    cfg = registry.get_config(arch)
    cache = jax.eval_shape(
        lambda: model.init_cache(None, cfg, shape.global_batch, shape.seq_len)
    )
    return float(sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(cache)))


def analytic_terms(arch: str, shape_name: str) -> dict:
    cfg, N, Na = _params(arch)
    shape = registry.get_shape(shape_name)
    B, S = shape.global_batch, shape.seq_len
    T = B * S if shape.kind != "decode" else B

    if shape.kind == "train":
        flops = 6.0 * Na * T + 3.0 * _attn_flops(cfg, S, B, False)
        # params: fp32 read fwd + read bwd + adam read(p,m,v)+write(p,m,v)
        param_traffic = N * 4 * 8.0
        act_traffic = cfg.n_layers * T * cfg.d_model * 2.0 * 12.0  # bf16, ~12 tensors w/ remat
        mem = param_traffic + act_traffic
        # collectives: FSDP all-gather (bf16) + grad reduce-scatter (fp32) +
        # 2 TP all-reduces/layer on activations (bf16)
        coll = 2.0 * N * 2 + 4.0 * N + cfg.n_layers * 2 * T * cfg.d_model * 2.0
    elif shape.kind == "prefill":
        flops = 2.0 * Na * T + _attn_flops(cfg, S, B, False)
        mem = N * 4.0 + cfg.n_layers * T * cfg.d_model * 2.0 * 8.0
        coll = 2.0 * N * 2 + cfg.n_layers * 2 * T * cfg.d_model * 2.0
    else:  # decode: one token, read all params + the whole KV cache
        flops = 2.0 * Na * T + _attn_flops(cfg, S, B, True)
        cache = _cache_bytes(arch, shape)
        mem = N * 4.0 + cache
        coll = cfg.n_layers * 2 * T * cfg.d_model * 2.0  # TP act exchanges
    return {
        "flops": flops,
        "mem_bytes": mem,
        "coll_bytes": coll,
        "t_compute": flops / (CHIPS * PEAK_FLOPS),
        "t_memory": mem / (CHIPS * HBM_BW),
        "t_collective": coll / (CHIPS * ICI_BW),
        "model_flops": (6.0 if shape.kind == "train" else 2.0) * Na * T,
    }


def analyze(path: str) -> list[dict]:
    rows = []
    for r in sorted(json.load(open(path)), key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "OK":
            rows.append({"arch": r["arch"], "shape": r["shape"], "status": r["status"],
                         "note": r.get("reason", r.get("error", ""))[:90]})
            continue
        a = analytic_terms(r["arch"], r["shape"])
        terms = {"compute": a["t_compute"], "memory": a["t_memory"],
                 "collective": a["t_collective"]}
        dom = max(terms, key=terms.get)
        t_dom = terms[dom]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "OK",
            "t_compute_s": a["t_compute"], "t_memory_s": a["t_memory"],
            "t_collective_s": a["t_collective"], "bottleneck": dom,
            "useful_ratio": a["model_flops"] / max(a["flops"], 1.0),
            "roofline_frac": a["t_compute"] / max(t_dom, 1e-30),
            "peak_GB_dev": r["peak_bytes"] / 1e9,
            "hlo_coll_ops": r["collectives"]["count"],
            "hlo_coll_bytes": r["collectives"]["total"],
            "fits_16GB": bool(r["peak_bytes"] < 16e9),
        })
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| roofline frac | peak GB/dev | fits 16G | HLO coll ops |")
    out = [hdr, "|" + "---|" * 10]
    for r in rows:
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"{r['status']}: {r.get('note','')} | - | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['bottleneck']} | {r['roofline_frac']:.2f} "
            f"| {r['peak_GB_dev']:.1f} | {'Y' if r['fits_16GB'] else 'N'} "
            f"| {r['hlo_coll_ops']} |"
        )
    return "\n".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="benchmarks/artifacts")
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--grad-sync", default="xla")
    args = ap.parse_args()
    path = os.path.join(args.artifacts, f"dryrun_{args.mesh}_{args.grad_sync}.json")
    rows = analyze(path)
    print(markdown(rows))
    out = os.path.join(args.artifacts, f"roofline_{args.mesh}_{args.grad_sync}.json")
    json.dump(rows, open(out, "w"), indent=1)
    print(f"\n[written] {out}")


if __name__ == "__main__":
    main()
