"""Benchmark harness: one function per paper table/figure + §Perf benches.

Prints ``name,us_per_call,derived`` CSV (DESIGN.md §7 maps names to paper
artifacts) and writes a machine-readable BENCH_netsim.json (CSV rows plus
the netsim perf records from benchmarks/common.PERF: per-step µs, sweep
wall-clock, compact-vs-dense speedup).  ``--full`` switches to paper-scale
simulation parameters; ``--only <substr>`` filters benches; ``--json ''``
disables the JSON dump.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default="BENCH_netsim.json",
                    help="output path for the machine-readable record")
    ap.add_argument("--profile", action="store_true",
                    help="per-phase compact-step timing rows (admit / "
                         "cascade / dcqcn / finish) for perf attribution")
    args = ap.parse_args()

    from benchmarks import common, paper_benches
    from benchmarks.bench_collectives import bench_collectives
    from benchmarks.bench_cosim import bench_cosim, bench_faults, \
        bench_telemetry
    from benchmarks.bench_flowcell import bench_flowcell
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_obs import bench_obs

    benches = list(paper_benches.ALL) + [bench_collectives, bench_kernels,
                                         bench_cosim, bench_faults,
                                         bench_telemetry, bench_obs,
                                         bench_flowcell]
    if args.profile:
        benches.append(paper_benches.bench_profile_phases)
    print("name,us_per_call,derived")
    t0 = time.time()
    for b in benches:
        if args.only and args.only not in b.__name__:
            continue
        try:
            b(fast=not args.full)
        except Exception as e:  # a failed bench must not hide the others
            print(f"{b.__name__},0.0,ERROR_{type(e).__name__}:_{str(e)[:120]}",
                  file=sys.stdout, flush=True)
    wall = time.time() - t0
    print(f"# total_wall_s,{wall:.1f},", flush=True)

    if args.json:
        from repro import obs

        record = dict(common.PERF)
        record["total_wall_s"] = round(wall, 1)
        record["rows"] = common.ROWS
        # provenance stamp on the file AND every dict section, so sections
        # merged across runs/machines stay individually attributable
        meta = obs.runmeta()
        for sec in record.values():
            if isinstance(sec, dict):
                sec.setdefault("runmeta", meta)
        record["runmeta"] = meta
        try:
            with open(args.json, "w") as f:
                json.dump(record, f, indent=2)
            print(f"# wrote {args.json}", flush=True)
        except OSError as e:  # never lose a long bench run to a bad path
            print(f"# could not write {args.json}: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
