"""Benchmark harness: one function per paper table/figure + §Perf benches.

Prints ``name,us_per_call,derived`` CSV (DESIGN.md §7 maps names to paper
artifacts).  ``--full`` switches to paper-scale simulation parameters;
``--only <substr>`` filters benches.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import paper_benches
    from benchmarks.bench_collectives import bench_collectives
    from benchmarks.bench_kernels import bench_kernels

    benches = list(paper_benches.ALL) + [bench_collectives, bench_kernels]
    print("name,us_per_call,derived")
    t0 = time.time()
    for b in benches:
        if args.only and args.only not in b.__name__:
            continue
        try:
            b(fast=not args.full)
        except Exception as e:  # a failed bench must not hide the others
            print(f"{b.__name__},0.0,ERROR_{type(e).__name__}:_{str(e)[:120]}",
                  file=sys.stdout, flush=True)
    print(f"# total_wall_s,{time.time()-t0:.1f},", flush=True)


if __name__ == "__main__":
    main()
