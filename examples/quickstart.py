"""Quickstart: the paper's mechanism in 40 lines.

1. A WQE (message) is split into N=4 equal sub-WQEs (SeqBalance Shaper).
2. Each sub-flow hashes to a path at the source ToR; congested paths are
   double-hashed around.
3. The destination mirrors ECN marks back; the table holds them for phi.
4. A CQE fires only when every sub-flow's bitmap bit is set.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import congestion_table as ctab, routing, shaper

N_PATHS, N_SUB, PHI = 8, 4, 32e-6

# --- 1. shaper: split one 1 MB WQE into 4 sub-WQEs on distinct QPs
size = jnp.asarray(1_000_000, jnp.int32)
sub_sizes = shaper.split_wqe(size, N_SUB)
src, dst, sport, dport = shaper.subflow_five_tuples(
    jnp.uint32(11), jnp.uint32(42), flow_id=jnp.uint32(7), n=N_SUB
)
print("sub-WQE sizes:", sub_sizes, "(sum:", int(sub_sizes.sum()), "bytes)")

# --- 2./3. congestion table: path 3 was reported congested just now
table = ctab.CongestionTable.create(1, N_PATHS)
table = ctab.mark_congested(table, jnp.array([0]), jnp.array([3]), now=0.0, phi=PHI)
inactive = ctab.inactive_row(table, jnp.array(0), now=10e-6)
paths = routing.select_paths(src, dst, sport, dport, inactive[None, :], N_PATHS)
print("inactive paths:", [i for i, b in enumerate(inactive.tolist()) if b])
print("chosen paths  :", paths.tolist(), "(never 3; sticky per sub-flow => no reordering)")

# --- 4. bitmap CQE: the app sees ONE completion, only when all ACKs are in
cqe = shaper.CQEState.create(1, N_SUB)
for i in range(N_SUB):
    cqe = shaper.ack_subwqe(cqe, jnp.array([0]), jnp.array([i]))
    print(f"ACK sub-WQE {i}: CQE ready = {bool(shaper.cqe_ready(cqe)[0])}")
