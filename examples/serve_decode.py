"""Serve a small model: batched prefill + greedy decode with KV cache.

Run: PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch import steps
from repro.models import model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=64)
ap.add_argument("--tokens", type=int, default=32)
args = ap.parse_args()

cfg = registry.get_config(args.arch, reduced=True).replace(dtype="float32")
params = model.init_params(jax.random.PRNGKey(0), cfg)
B, S = args.batch, args.prompt_len
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab)}
if cfg.is_encoder_decoder:
    batch["frames"] = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_frames, cfg.d_model))
if cfg.n_vision_tokens:
    batch["vision_embeds"] = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.n_vision_tokens, cfg.d_model))

serve_step = jax.jit(steps.make_serve_step(cfg))
t0 = time.time()
logits, cache = jax.jit(model.prefill, static_argnums=(1, 3))(
    params, cfg, batch, S + args.tokens)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
print(f"prefill[{B}x{S}] {time.time()-t0:.2f}s")

outs = [tok]
t0 = time.time()
for i in range(args.tokens - 1):
    tok, _, cache = serve_step(params, tok, cache)
    outs.append(tok)
dt = time.time() - t0
seq = jnp.concatenate(outs, axis=1)
print(f"decoded {args.tokens} tokens/seq: {dt/max(args.tokens-1,1)*1e3:.1f} ms/step")
print("sample token ids:", seq[0, :16].tolist())
