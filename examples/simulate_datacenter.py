"""Simulate the paper's large-scale setup (8 leaves x 12 spines x 128
hosts @100G) and compare SeqBalance against ECMP/LetFlow/CONGA/DRILL.

Runs on the active-window vmapped engine (netsim/sweep.py) — all five
schemes as concurrent sweep jobs; pass --dense for the O(F) oracle.

Run: PYTHONPATH=src python examples/simulate_datacenter.py [--elephants]
"""
import argparse
import time

import numpy as np

from repro.netsim import engine, metrics, sweep, topology, workloads

ap = argparse.ArgumentParser()
ap.add_argument("--elephants", action="store_true",
                help="AI-training traffic mode (few large flows)")
ap.add_argument("--load", type=float, default=0.6)
ap.add_argument("--dense", action="store_true",
                help="use the dense O(F) oracle engine instead")
args = ap.parse_args()

topo = topology.sim_2tier()
wl = "fixed:10e6" if args.elephants else "websearch"
trace = workloads.poisson_trace(workloads.TraceConfig(
    workload=wl, load=args.load, duration_s=4e-3, n_hosts=topo.n_hosts,
    host_bw=100e9, seed=1, hosts_per_leaf=topo.hosts_per_leaf,
    load_base_bw=8 * 12 * 100e9,
))
print(f"workload={wl} load={args.load} flows={int(trace.valid.sum())}")

schemes = ("ecmp", "letflow", "conga", "drill", "seqbalance")
t0 = time.time()
if args.dense:
    runs = {}
    for scheme in schemes:
        cfg = engine.SimConfig(scheme=scheme, duration_s=16e-3)
        runs[scheme] = engine.simulate(topo, cfg, trace)
else:
    jobs = [(topo, engine.SimConfig(scheme=s, duration_s=16e-3), [trace])
            for s in schemes]
    out = sweep.run_jobs(jobs)
    runs = {s: (r[0], o[0]) for s, (r, o) in zip(schemes, out)}
wall = time.time() - t0

for scheme in schemes:
    st, outs = runs[scheme]
    s = metrics.fct_stats(st, trace, topo, 100e9)
    imb = metrics.throughput_imbalance(outs)
    print(f"{scheme:11s} avg_slowdown={s['avg_slowdown']:7.2f} "
          f"p99={s['p99_slowdown']:8.2f} completion={s['completion_rate']:.3f} "
          f"imbalance_median={np.median(imb) if len(imb) else -1:.3f}")
print(f"engine={'dense' if args.dense else 'active-window'} wall={wall:.1f}s")
