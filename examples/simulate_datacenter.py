"""Simulate the paper's large-scale setup (8 leaves x 12 spines x 128
hosts @100G) and compare SeqBalance against ECMP/LetFlow/CONGA/DRILL.

Run: PYTHONPATH=src python examples/simulate_datacenter.py [--elephants]
"""
import argparse

import numpy as np

from repro.netsim import engine, metrics, topology, workloads

ap = argparse.ArgumentParser()
ap.add_argument("--elephants", action="store_true",
                help="AI-training traffic mode (few large flows)")
ap.add_argument("--load", type=float, default=0.6)
args = ap.parse_args()

topo = topology.sim_2tier()
wl = "fixed:10e6" if args.elephants else "websearch"
trace = workloads.poisson_trace(workloads.TraceConfig(
    workload=wl, load=args.load, duration_s=4e-3, n_hosts=topo.n_hosts,
    host_bw=100e9, seed=1, hosts_per_leaf=topo.hosts_per_leaf,
    load_base_bw=8 * 12 * 100e9,
))
print(f"workload={wl} load={args.load} flows={int(trace.valid.sum())}")

for scheme in ("ecmp", "letflow", "conga", "drill", "seqbalance"):
    cfg = engine.SimConfig(scheme=scheme, duration_s=16e-3)
    st, outs = engine.simulate(topo, cfg, trace)
    s = metrics.fct_stats(st, trace, topo, 100e9)
    imb = metrics.throughput_imbalance(outs)
    print(f"{scheme:11s} avg_slowdown={s['avg_slowdown']:7.2f} "
          f"p99={s['p99_slowdown']:8.2f} completion={s['completion_rate']:.3f} "
          f"imbalance_median={np.median(imb) if len(imb) else -1:.3f}")
