#!/usr/bin/env python
"""Bench regression gate (scripts/ci.sh).

Compares a freshly measured fig12 fast-sweep record (benchmarks/run.py
--only netsim_speedup) against the committed BENCH_netsim.json baseline:

  * per_step_us_compact may not regress more than --max-regress (default
    30 %) over the baseline's value;
  * max_stat_diff_pct (compact vs dense-oracle FCT stats) may not exceed
    --max-stat-diff (default 0.01 %);
  * the sweep must be spill-free (spill-free runs are the ones that match
    the oracle bit-for-bit).

The baseline record may contain several runs (before/after rows across
PRs); the gate reads the top-level "fig12_sweep" entry — the current one.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh bench JSON (the run under test)")
    ap.add_argument("baseline", help="committed BENCH_netsim.json")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="allowed fractional per-step slowdown vs baseline")
    ap.add_argument("--max-stat-diff", type=float, default=0.01,
                    help="allowed compact-vs-dense stat divergence (%%)")
    args = ap.parse_args()

    with open(args.new) as f:
        new = json.load(f).get("fig12_sweep")
    with open(args.baseline) as f:
        base = json.load(f).get("fig12_sweep")
    if not new:
        print("FAIL: new record has no fig12_sweep entry "
              "(did --only netsim_speedup run?)")
        return 1
    if not base:
        print("WARN: baseline has no fig12_sweep entry; gating stat-diff only")

    ok = True
    per_step = new["per_step_us_compact"]
    if base:
        limit = base["per_step_us_compact"] * (1.0 + args.max_regress)
        verdict = "OK" if per_step <= limit else "FAIL"
        ok &= per_step <= limit
        print(f"{verdict}: per_step_us_compact {per_step:.1f} "
              f"(baseline {base['per_step_us_compact']:.1f}, "
              f"limit {limit:.1f})")
        if per_step > limit:
            print("      note: the baseline is wall-clock from the machine "
                  "that committed BENCH_netsim.json; on unrelated/slower "
                  "hardware set REPRO_CI_SKIP_BENCH_GATE=1")

    diff = new["max_stat_diff_pct"]
    verdict = "OK" if diff <= args.max_stat_diff else "FAIL"
    ok &= diff <= args.max_stat_diff
    print(f"{verdict}: max_stat_diff_pct {diff:.4f} "
          f"(limit {args.max_stat_diff})")

    spill = new.get("spill_steps", 0)
    verdict = "OK" if spill == 0 else "FAIL"
    ok &= spill == 0
    print(f"{verdict}: spill_steps {spill}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
