#!/usr/bin/env python
"""Bench regression gate (scripts/ci.sh).

Compares a freshly measured fig12 fast-sweep record (benchmarks/run.py
--only netsim_speedup) against the committed BENCH_netsim.json baseline:

  * per_step_us_compact may not regress more than --max-regress (default
    30 %) over the baseline's value;
  * max_stat_diff_pct (compact vs dense-oracle FCT stats) may not exceed
    --max-stat-diff (default 0.01 %);
  * the sweep must be spill-free (spill-free runs are the ones that match
    the oracle bit-for-bit).

The baseline record may contain several runs (before/after rows across
PRs); the gate reads the top-level "fig12_sweep" entry — the current one.

``--cosim`` switches to the co-simulation convergence gate instead: rows
under "cosim" are matched by (topo, scheme, ring, seed) and the run fails
when a scenario's convergence-epoch count regressed by MORE than 1 vs the
committed baseline, stopped converging at all, or — for solo-run rows,
the only ones carrying ``rebuilds_after_first`` — rebuilt sweep
executables after the first epoch (the traced-capacity compile-reuse
contract — epochs must share one program regardless of fault state).
"""
from __future__ import annotations

import argparse
import json
import sys


def check_cosim(new: dict | None, base: dict | None) -> int:
    if not new or not new.get("rows"):
        print("FAIL: new record has no cosim rows (did --only cosim run?)")
        return 1
    base_rows = {}
    for r in (base or {}).get("rows", []):
        base_rows[(r["topo"], r["scheme"], r["ring"], r.get("seed", 0))] = r
    if not base_rows:
        print("WARN: baseline has no cosim rows; gating convergence + "
              "rebuilds only")
    ok = True
    for r in new["rows"]:
        key = (r["topo"], r["scheme"], r["ring"], r.get("seed", 0))
        name = "/".join(str(k) for k in key)
        conv = r.get("convergence_epochs")
        if conv is None:
            ok = False
            print(f"FAIL: {name} no longer converges")
            continue
        b = base_rows.get(key)
        if b is not None and b.get("convergence_epochs") is not None:
            limit = b["convergence_epochs"] + 1
            verdict = "OK" if conv <= limit else "FAIL"
            ok &= conv <= limit
            print(f"{verdict}: {name} convergence_epochs {conv} "
                  f"(baseline {b['convergence_epochs']}, limit {limit})")
        else:
            print(f"OK: {name} convergence_epochs {conv} (no baseline row)")
        # only solo-run rows carry the key — concurrent grid workers
        # cross-contaminate the process-global build counter, so the bench
        # omits it for them
        rb = r.get("rebuilds_after_first")
        if rb:
            ok = False
            print(f"FAIL: {name} rebuilt {rb} sweep executables after "
                  f"epoch 0 (traced-capacity reuse broken)")
    return 0 if ok else 1


def check_faults(new: dict | None, base: dict | None,
                 max_regress: float = 0.30) -> int:
    """Chaos-campaign gate (BENCH_netsim.json["faults"]): every campaign
    cell must survive (crashed_cells == 0 — the crash-proof pool salvaged
    nothing), every scenario must still converge after its fault mix, and
    the worst censored-p99 epoch may not regress more than ``max_regress``
    vs the committed baseline (the sim is seeded/deterministic, so a drift
    beyond noise is a behavior change, not jitter)."""
    if not new or not new.get("rows"):
        print("FAIL: new record has no faults rows (did --only faults run?)")
        return 1
    ok = True
    crashed = new.get("crashed_cells", 0)
    verdict = "OK" if crashed == 0 else "FAIL"
    ok &= crashed == 0
    print(f"{verdict}: crashed_cells {crashed} (salvaged campaign cells)")
    base_rows = {}
    for r in (base or {}).get("rows", []):
        base_rows[(r["topo"], r["scheme"], r["ring"], r.get("seed", 0))] = r
    if not base_rows:
        print("WARN: baseline has no faults rows; gating convergence + "
              "crashes only")
    for r in new["rows"]:
        key = (r.get("topo"), r.get("scheme"), r.get("ring"), r.get("seed", 0))
        name = "/".join(str(k) for k in key)
        if r.get("crashed"):
            ok = False
            print(f"FAIL: {name} crashed ({r.get('error', '?')[:80]})")
            continue
        conv = r.get("convergence_epochs")
        if conv is None:
            ok = False
            print(f"FAIL: {name} never reconverges after the campaign")
            continue
        b = base_rows.get(key)
        if b is not None and b.get("p99_worst_us"):
            limit = b["p99_worst_us"] * (1.0 + max_regress)
            p99 = r.get("p99_worst_us", float("inf"))
            verdict = "OK" if p99 <= limit else "FAIL"
            ok &= p99 <= limit
            print(f"{verdict}: {name} worst censored p99 {p99:.1f}us "
                  f"(baseline {b['p99_worst_us']:.1f}us, limit {limit:.1f}us)"
                  f" conv_epochs {conv}")
        else:
            print(f"OK: {name} conv_epochs {conv} (no baseline row)")
        rb = r.get("rebuilds_after_first")
        if rb:
            ok = False
            print(f"FAIL: {name} rebuilt {rb} sweep executables after "
                  f"epoch 0 (campaign operands must share one program)")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh bench JSON (the run under test)")
    ap.add_argument("baseline", help="committed BENCH_netsim.json")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="allowed fractional per-step slowdown vs baseline")
    ap.add_argument("--max-stat-diff", type=float, default=0.01,
                    help="allowed compact-vs-dense stat divergence (%%)")
    ap.add_argument("--cosim", action="store_true",
                    help="gate the cosim convergence rows instead of the "
                         "fig12 sweep")
    ap.add_argument("--faults", action="store_true",
                    help="gate the chaos-campaign rows (crashed cells, "
                         "reconvergence, worst censored p99) instead of "
                         "the fig12 sweep")
    args = ap.parse_args()

    if args.cosim:
        with open(args.new) as f:
            new_c = json.load(f).get("cosim")
        with open(args.baseline) as f:
            base_c = json.load(f).get("cosim")
        return check_cosim(new_c, base_c)

    if args.faults:
        with open(args.new) as f:
            new_f = json.load(f).get("faults")
        with open(args.baseline) as f:
            base_f = json.load(f).get("faults")
        return check_faults(new_f, base_f, max_regress=args.max_regress)

    with open(args.new) as f:
        new = json.load(f).get("fig12_sweep")
    with open(args.baseline) as f:
        base = json.load(f).get("fig12_sweep")
    if not new:
        print("FAIL: new record has no fig12_sweep entry "
              "(did --only netsim_speedup run?)")
        return 1
    if not base:
        print("WARN: baseline has no fig12_sweep entry; gating stat-diff only")

    ok = True
    per_step = new["per_step_us_compact"]
    if base:
        limit = base["per_step_us_compact"] * (1.0 + args.max_regress)
        verdict = "OK" if per_step <= limit else "FAIL"
        ok &= per_step <= limit
        print(f"{verdict}: per_step_us_compact {per_step:.1f} "
              f"(baseline {base['per_step_us_compact']:.1f}, "
              f"limit {limit:.1f})")
        if per_step > limit:
            print("      note: the baseline is wall-clock from the machine "
                  "that committed BENCH_netsim.json; on unrelated/slower "
                  "hardware set REPRO_CI_SKIP_BENCH_GATE=1")

    diff = new["max_stat_diff_pct"]
    verdict = "OK" if diff <= args.max_stat_diff else "FAIL"
    ok &= diff <= args.max_stat_diff
    print(f"{verdict}: max_stat_diff_pct {diff:.4f} "
          f"(limit {args.max_stat_diff})")

    spill = new.get("spill_steps", 0)
    verdict = "OK" if spill == 0 else "FAIL"
    ok &= spill == 0
    print(f"{verdict}: spill_steps {spill}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
