#!/usr/bin/env python
"""Bench regression gate (scripts/ci.sh).

Compares a freshly measured fig12 fast-sweep record (benchmarks/run.py
--only netsim_speedup) against the committed BENCH_netsim.json baseline:

  * per_step_us_compact may not regress more than --max-regress (default
    30 %) over the baseline's value;
  * max_stat_diff_pct (compact vs dense-oracle FCT stats) may not exceed
    --max-stat-diff (default 0.01 %);
  * the sweep must be spill-free (spill-free runs are the ones that match
    the oracle bit-for-bit).

The baseline record may contain several runs (before/after rows across
PRs); the gate reads the top-level "fig12_sweep" entry — the current one.

``--adaptive`` switches to the adaptive-dt gate (BENCH_netsim.json
["adaptive_dt"]): adaptive-vs-fixed stat divergence over --max-stat-diff,
a speedup below the baseline's recorded floors, a collective run that
never fast-forwards, or any executable rebuild after the first adaptive
dispatch all fail.

``--cosim`` switches to the co-simulation convergence gate instead: rows
under "cosim" are matched by (topo, scheme, ring, seed) and the run fails
when a scenario's convergence-epoch count regressed by MORE than 1 vs the
committed baseline, stopped converging at all, or — for solo-run rows,
the only ones carrying ``rebuilds_after_first`` — rebuilt sweep
executables after the first epoch (the traced-capacity compile-reuse
contract — epochs must share one program regardless of fault state).
"""
from __future__ import annotations

import argparse
import json
import sys


def check_cosim(new: dict | None, base: dict | None) -> int:
    if not new or not new.get("rows"):
        print("FAIL: new record has no cosim rows (did --only cosim run?)")
        return 1
    base_rows = {}
    for r in (base or {}).get("rows", []):
        base_rows[(r["topo"], r["scheme"], r["ring"], r.get("seed", 0))] = r
    if not base_rows:
        print("WARN: baseline has no cosim rows; gating convergence + "
              "rebuilds only")
    ok = True
    for r in new["rows"]:
        key = (r["topo"], r["scheme"], r["ring"], r.get("seed", 0))
        name = "/".join(str(k) for k in key)
        conv = r.get("convergence_epochs")
        if conv is None:
            ok = False
            print(f"FAIL: {name} no longer converges")
            continue
        b = base_rows.get(key)
        if b is not None and b.get("convergence_epochs") is not None:
            limit = b["convergence_epochs"] + 1
            verdict = "OK" if conv <= limit else "FAIL"
            ok &= conv <= limit
            print(f"{verdict}: {name} convergence_epochs {conv} "
                  f"(baseline {b['convergence_epochs']}, limit {limit})")
        else:
            print(f"OK: {name} convergence_epochs {conv} (no baseline row)")
        # only solo-run rows carry the key — concurrent grid workers
        # cross-contaminate the process-global build counter, so the bench
        # omits it for them
        rb = r.get("rebuilds_after_first")
        if rb:
            ok = False
            print(f"FAIL: {name} rebuilt {rb} sweep executables after "
                  f"epoch 0 (traced-capacity reuse broken)")
    return 0 if ok else 1


def check_faults(new: dict | None, base: dict | None,
                 max_regress: float = 0.30) -> int:
    """Chaos-campaign gate (BENCH_netsim.json["faults"]): every campaign
    cell must survive (crashed_cells == 0 — the crash-proof pool salvaged
    nothing), every scenario must still converge after its fault mix, and
    the worst censored-p99 epoch may not regress more than ``max_regress``
    vs the committed baseline (the sim is seeded/deterministic, so a drift
    beyond noise is a behavior change, not jitter)."""
    if not new or not new.get("rows"):
        print("FAIL: new record has no faults rows (did --only faults run?)")
        return 1
    ok = True
    crashed = new.get("crashed_cells", 0)
    verdict = "OK" if crashed == 0 else "FAIL"
    ok &= crashed == 0
    print(f"{verdict}: crashed_cells {crashed} (salvaged campaign cells)")
    base_rows = {}
    for r in (base or {}).get("rows", []):
        base_rows[(r["topo"], r["scheme"], r["ring"], r.get("seed", 0))] = r
    if not base_rows:
        print("WARN: baseline has no faults rows; gating convergence + "
              "crashes only")
    for r in new["rows"]:
        key = (r.get("topo"), r.get("scheme"), r.get("ring"), r.get("seed", 0))
        name = "/".join(str(k) for k in key)
        if r.get("crashed"):
            ok = False
            print(f"FAIL: {name} crashed ({r.get('error', '?')[:80]})")
            continue
        conv = r.get("convergence_epochs")
        if conv is None:
            ok = False
            print(f"FAIL: {name} never reconverges after the campaign")
            continue
        b = base_rows.get(key)
        if b is not None and b.get("p99_worst_us"):
            limit = b["p99_worst_us"] * (1.0 + max_regress)
            p99 = r.get("p99_worst_us", float("inf"))
            verdict = "OK" if p99 <= limit else "FAIL"
            ok &= p99 <= limit
            print(f"{verdict}: {name} worst censored p99 {p99:.1f}us "
                  f"(baseline {b['p99_worst_us']:.1f}us, limit {limit:.1f}us)"
                  f" conv_epochs {conv}")
        else:
            print(f"OK: {name} conv_epochs {conv} (no baseline row)")
        rb = r.get("rebuilds_after_first")
        if rb:
            ok = False
            print(f"FAIL: {name} rebuilt {rb} sweep executables after "
                  f"epoch 0 (campaign operands must share one program)")
    return 0 if ok else 1


def check_telemetry(new: dict | None, base: dict | None) -> int:
    """Degraded-telemetry gate (BENCH_netsim.json["telemetry"]), the ISSUE 7
    acceptance criteria as checks WITHIN the fresh run:

      * every row: plan versions strictly monotone across the run and zero
        refused newer-plan applications (the versioned-application
        invariant held live);
      * the perfect-channel three_tier cell is bit-identical to the
        no-channel cell (p99 curves equal element-wise);
      * the loss30_delay2 three_tier cell — 30 % report loss, 2-epoch
        delay, killed agg switch — reconverges within +1 epoch of the
        LOSSLESS SAME-DELAY baseline (delay2 cell); the delay-only penalty
        itself is bounded by the delay;
      * the blackout cell entered safe mode, exited it after the channel
        healed, and reconverged;
      * grid cells with loss <= 0.3 all converge, each within +1 epoch of
        the lossless cell at the same delay;

    plus the cross-run regression check: any cell's convergence-epoch
    count may not regress by more than 1 vs the committed baseline."""
    if not new or not new.get("rows"):
        print("FAIL: new record has no telemetry rows "
              "(did --only telemetry run?)")
        return 1
    ok = True
    rows = {r.get("cell"): r for r in new["rows"]}

    for r in new["rows"]:
        name = r.get("cell", "?")
        if not r.get("version_monotone", False):
            ok = False
            print(f"FAIL: {name} plan versions not strictly monotone")
        refused = r.get("plan_refused", 0)
        if refused:
            ok = False
            print(f"FAIL: {name} refused {refused} genuinely newer plans")
    print(f"OK: plan versions monotone, 0 refusals across {len(new['rows'])} "
          "rows" if ok else "    (version/refusal failures above)")

    def conv(cell):
        r = rows.get(cell)
        return None if r is None else r.get("convergence_epochs")

    # perfect channel == no channel, bit for bit
    if rows.get("none") and rows.get("perfect"):
        same = rows["none"]["p99_us"] == rows["perfect"]["p99_us"]
        verdict = "OK" if same else "FAIL"
        ok &= same
        print(f"{verdict}: perfect-channel p99 curve bit-identical to "
              "no-channel")
    else:
        ok = False
        print("FAIL: missing none/perfect acceptance cells")

    # lossy-delayed reconvergence vs the lossless same-delay baseline
    c_delay, c_lossy = conv("delay2"), conv("loss30_delay2")
    if c_delay is None or c_lossy is None:
        ok = False
        print(f"FAIL: acceptance cells did not converge "
              f"(delay2={c_delay}, loss30_delay2={c_lossy})")
    else:
        good = c_lossy <= c_delay + 1
        verdict = "OK" if good else "FAIL"
        ok &= good
        print(f"{verdict}: loss30_delay2 conv {c_lossy} vs lossless "
              f"same-delay {c_delay} (limit +1)")
        c0 = conv("perfect")
        if c0 is not None:
            good = c_delay <= c0 + 2  # a 2-epoch report delay may cost 2
            verdict = "OK" if good else "FAIL"
            ok &= good
            print(f"{verdict}: delay2 conv {c_delay} vs perfect {c0} "
                  f"(limit +delay)")

    # blackout: safe mode entered, exited, reconverged
    b = rows.get("blackout")
    if b is None:
        ok = False
        print("FAIL: missing blackout acceptance cell")
    else:
        safe = b.get("safe_epochs", [])
        entered = len(safe) > 0
        exited = bool(safe) and max(safe) < b["epochs"] - 1 \
            and not b["safe_mode"][-1]
        reconv = b.get("convergence_epochs") is not None
        good = entered and exited and reconv
        verdict = "OK" if good else "FAIL"
        ok &= good
        print(f"{verdict}: blackout safe_epochs {safe} "
              f"(entered {entered}, exited {exited}, "
              f"conv {b.get('convergence_epochs')})")

    # the loss x delay grid: bounded degradation wherever loss <= 0.3
    lossless = {}
    for r in new["rows"]:
        if str(r.get("cell", "")).startswith("grid_") and r["loss"] == 0.0:
            lossless[r["delay"]] = r.get("convergence_epochs")
    for r in new["rows"]:
        if not str(r.get("cell", "")).startswith("grid_"):
            continue
        if r["loss"] > 0.3:
            continue  # 50 % loss is reported, not gated
        c, ref = r.get("convergence_epochs"), lossless.get(r["delay"])
        name = r["cell"]
        if c is None or ref is None:
            ok = False
            print(f"FAIL: {name} did not converge (conv {c}, lossless "
                  f"same-delay {ref})")
            continue
        good = c <= ref + 1
        verdict = "OK" if good else "FAIL"
        ok &= good
        print(f"{verdict}: {name} conv {c} (lossless d={r['delay']}: {ref}, "
              "limit +1)")

    # cross-run: convergence may not regress > 1 vs the committed baseline
    base_rows = {r.get("cell"): r for r in (base or {}).get("rows", [])}
    if not base_rows:
        print("WARN: baseline has no telemetry rows; in-run gates only")
    for r in new["rows"]:
        b = base_rows.get(r.get("cell"))
        if b is None or b.get("convergence_epochs") is None:
            continue
        c = r.get("convergence_epochs")
        limit = b["convergence_epochs"] + 1
        good = c is not None and c <= limit
        ok &= good
        if not good:
            print(f"FAIL: {r['cell']} convergence_epochs {c} regressed "
                  f"(baseline {b['convergence_epochs']}, limit {limit})")
    return 0 if ok else 1


def check_adaptive(new: dict | None, base: dict | None,
                   max_stat_diff: float = 0.01) -> int:
    """Adaptive-dt gate (BENCH_netsim.json["adaptive_dt"], DESIGN.md §15):

      * adaptive-vs-fixed FCT stat divergence <= ``max_stat_diff`` percent
        on BOTH regimes (the tolerance model — adaptive is an
        approximation only where the quiescence predicate proved it
        exact, so divergence beyond float noise means the predicate
        admitted a non-quiescent span);
      * the sparse-collective and fig12 speedups may not fall below the
        BASELINE's recorded floors (collective: the >= 2x acceptance bar;
        fig12: the parity guard — event-dense traffic fast-forwards
        nothing, so the floor pins the predicate overhead at ~free);
      * the collective trace must actually fast-forward (ff_steps > 0) —
        a silently-disabled predicate would pass every other check;
      * zero executable-cache builds after the first adaptive dispatch
        (adaptivity is data-dependent inside one program, never a
        recompile)."""
    if not new:
        print("FAIL: new record has no adaptive_dt entry "
              "(did --only adaptive run?)")
        return 1
    ok = True
    diff = new.get("max_stat_diff_pct", float("inf"))
    verdict = "OK" if diff <= max_stat_diff else "FAIL"
    ok &= diff <= max_stat_diff
    print(f"{verdict}: adaptive max_stat_diff_pct {diff:.4f} "
          f"(limit {max_stat_diff})")

    floors = (base or {}).get("floors") or new.get("floors") or {}
    if not (base or {}).get("floors"):
        print("WARN: baseline has no adaptive floors; using the fresh "
              "record's own")
    for regime, key in (("collective", "collective_speedup"),
                        ("fig12", "fig12_speedup")):
        sp = (new.get(regime) or {}).get("speedup")
        floor = floors.get(key)
        if sp is None or floor is None:
            ok = False
            print(f"FAIL: missing {regime} speedup or {key} floor")
            continue
        verdict = "OK" if sp >= floor else "FAIL"
        ok &= sp >= floor
        print(f"{verdict}: {regime} speedup {sp:.2f}x (floor {floor}x)")
        if sp < floor and regime == "collective":
            print("      note: floors are wall-clock from the machine that "
                  "committed BENCH_netsim.json; on unrelated/slower "
                  "hardware set REPRO_CI_SKIP_BENCH_GATE=1")

    ff = (new.get("collective") or {}).get("ff_steps", 0)
    verdict = "OK" if ff > 0 else "FAIL"
    ok &= ff > 0
    print(f"{verdict}: collective ff_steps {ff} (fast-forward engaged)")

    rb = new.get("rebuilds_after_first", 0)
    verdict = "OK" if rb == 0 else "FAIL"
    ok &= rb == 0
    print(f"{verdict}: rebuilds_after_first {rb}")
    return 0 if ok else 1


def check_obs(new: dict | None, base: dict | None) -> int:
    """Observability gate (BENCH_netsim.json["obs"], DESIGN.md §16):

      * warm per-dispatch recording overhead <= the BASELINE's
        ``max_overhead_pct`` floor (5% at introduction) — the traced ring
        buffer must stay effectively free;
      * zero executable-cache builds after the recorder's first warm
        dispatch of a shape (recording may never trigger a recompile);
      * the killed-agg-spine co-sim flight log covered EVERY epoch, every
        epoch carried an in-sim drain, and the campaign summed to zero
        new builds after epoch 0."""
    if not new:
        print("FAIL: new record has no obs entry (did --only obs run?)")
        return 1
    ok = True
    floors = (base or {}).get("floors") or new.get("floors") or {}
    if not (base or {}).get("floors"):
        print("WARN: baseline has no obs floors; using the fresh record's own")
    limit = floors.get("max_overhead_pct", 5.0)
    ov = new.get("overhead_pct", float("inf"))
    verdict = "OK" if ov <= limit else "FAIL"
    ok &= ov <= limit
    print(f"{verdict}: recording overhead {ov:+.2f}% (limit {limit}%)")
    if ov > limit:
        print("      note: overhead is wall-clock-relative; on a loaded or "
              "unrelated machine set REPRO_CI_SKIP_BENCH_GATE=1")

    rb = new.get("rebuilds_warm", 0)
    verdict = "OK" if rb == 0 else "FAIL"
    ok &= rb == 0
    print(f"{verdict}: rebuilds after warm recorded dispatch {rb}")

    cs = new.get("cosim") or {}
    cover = cs.get("flight_epochs", -1) == cs.get("epochs", -2)
    verdict = "OK" if cover else "FAIL"
    ok &= cover
    print(f"{verdict}: flight log covered {cs.get('flight_epochs')}/"
          f"{cs.get('epochs')} cosim epochs")

    insim = bool(cs.get("insim_every_epoch"))
    verdict = "OK" if insim else "FAIL"
    ok &= insim
    print(f"{verdict}: in-sim drain on every epoch record: {insim}")

    rb0 = cs.get("rebuilds_after_epoch0", -1)
    verdict = "OK" if rb0 == 0 else "FAIL"
    ok &= rb0 == 0
    print(f"{verdict}: cosim rebuilds after epoch 0: {rb0}")
    return 0 if ok else 1


def check_flowcell(new: dict | None, base: dict | None) -> int:
    """Flowcell/reordering-cost gate (BENCH_netsim.json["flowcell"],
    DESIGN.md §17):

      * the acceptance shape must hold IN the fresh run: flowcell spraying
        beats SeqBalance's censored p99 only in the cost-free arm
        (reorder=None) and loses at the strictest go-back-N budget on the
        symmetric fabric — the paper's no-reordering trade, quantified;
      * the hetero (mixed 100G/400G) grid must be present — the fabric
        where inter-path skew is structural, not transient;
      * zero sweep-executable rebuilds after epoch 0 in the solo co-sim
        with flowcells and the reorder budget live (spray is a traced
        trace column, the budget a traced scalar — neither may recompile);
      * the degenerate arms (flowcells=1 plan, reorder=0 on an unsprayed
        trace) must match the classic path with stat diff EXACTLY 0."""
    if not new:
        print("FAIL: new record has no flowcell entry "
              "(did --only flowcell run?)")
        return 1
    ok = True

    wins = bool(new.get("free_beats_seqbalance"))
    verdict = "OK" if wins else "FAIL"
    ok &= wins
    print(f"{verdict}: cost-free flowcell beats SeqBalance p99: {wins}")

    loses = bool(new.get("gbn_loses_on_symmetric"))
    verdict = "OK" if loses else "FAIL"
    ok &= loses
    print(f"{verdict}: strict-budget flowcell loses to SeqBalance on the "
          f"symmetric fabric: {loses}")

    het = (new.get("grids") or {}).get("hetero") or {}
    has_het = bool(het) and "flowcell_free" in het and "seqbalance" in het
    verdict = "OK" if has_het else "FAIL"
    ok &= has_het
    print(f"{verdict}: hetero grid recorded ({len(het)} arms)")

    rb = new.get("rebuilds_after_first", -1)
    verdict = "OK" if rb == 0 else "FAIL"
    ok &= rb == 0
    print(f"{verdict}: cosim rebuilds after epoch 0 with flowcells live: "
          f"{rb}")

    diff = new.get("degenerate_stat_diff", float("inf"))
    verdict = "OK" if diff == 0.0 else "FAIL"
    ok &= diff == 0.0
    print(f"{verdict}: degenerate-arm stat diff {diff} (must be exactly 0)")

    if base and base.get("grids"):
        b_sym = (base["grids"].get("symmetric") or {}).get("flowcell_free")
        n_sym = (new["grids"].get("symmetric") or {}).get("flowcell_free")
        if b_sym and n_sym:
            limit = b_sym["p99_us"] * 1.30
            good = n_sym["p99_us"] <= limit
            verdict = "OK" if good else "FAIL"
            ok &= good
            print(f"{verdict}: cost-free flowcell p99 {n_sym['p99_us']:.0f}us"
                  f" (baseline {b_sym['p99_us']:.0f}us, limit {limit:.0f}us)")
    else:
        print("WARN: baseline has no flowcell grids; in-run gates only")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh bench JSON (the run under test)")
    ap.add_argument("baseline", help="committed BENCH_netsim.json")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="allowed fractional per-step slowdown vs baseline")
    ap.add_argument("--max-stat-diff", type=float, default=0.01,
                    help="allowed compact-vs-dense stat divergence (%%)")
    ap.add_argument("--cosim", action="store_true",
                    help="gate the cosim convergence rows instead of the "
                         "fig12 sweep")
    ap.add_argument("--faults", action="store_true",
                    help="gate the chaos-campaign rows (crashed cells, "
                         "reconvergence, worst censored p99) instead of "
                         "the fig12 sweep")
    ap.add_argument("--adaptive", action="store_true",
                    help="gate the adaptive-dt record (stat divergence vs "
                         "fixed dt, speedup floors, fast-forward engaged, "
                         "zero rebuilds) instead of the fig12 sweep")
    ap.add_argument("--obs", action="store_true",
                    help="gate the observability record (recording overhead "
                         "floor, zero recorder rebuilds, full flight-log "
                         "epoch coverage) instead of the fig12 sweep")
    ap.add_argument("--flowcell", action="store_true",
                    help="gate the flowcell/reordering-cost record (free-arm "
                         "win + strict-budget loss vs SeqBalance, hetero "
                         "grid present, zero rebuilds, exact degenerate "
                         "stat match) instead of the fig12 sweep")
    ap.add_argument("--telemetry", action="store_true",
                    help="gate the degraded-telemetry rows (perfect-channel "
                         "bit-identity, lossy/delayed reconvergence, plan-"
                         "version monotonicity, blackout safe-mode) instead "
                         "of the fig12 sweep")
    args = ap.parse_args()

    if args.adaptive:
        with open(args.new) as f:
            new_a = json.load(f).get("adaptive_dt")
        with open(args.baseline) as f:
            base_a = json.load(f).get("adaptive_dt")
        return check_adaptive(new_a, base_a,
                              max_stat_diff=args.max_stat_diff)

    if args.obs:
        with open(args.new) as f:
            new_o = json.load(f).get("obs")
        with open(args.baseline) as f:
            base_o = json.load(f).get("obs")
        return check_obs(new_o, base_o)

    if args.flowcell:
        with open(args.new) as f:
            new_fc = json.load(f).get("flowcell")
        with open(args.baseline) as f:
            base_fc = json.load(f).get("flowcell")
        return check_flowcell(new_fc, base_fc)

    if args.telemetry:
        with open(args.new) as f:
            new_t = json.load(f).get("telemetry")
        with open(args.baseline) as f:
            base_t = json.load(f).get("telemetry")
        return check_telemetry(new_t, base_t)

    if args.cosim:
        with open(args.new) as f:
            new_c = json.load(f).get("cosim")
        with open(args.baseline) as f:
            base_c = json.load(f).get("cosim")
        return check_cosim(new_c, base_c)

    if args.faults:
        with open(args.new) as f:
            new_f = json.load(f).get("faults")
        with open(args.baseline) as f:
            base_f = json.load(f).get("faults")
        return check_faults(new_f, base_f, max_regress=args.max_regress)

    with open(args.new) as f:
        new = json.load(f).get("fig12_sweep")
    with open(args.baseline) as f:
        base = json.load(f).get("fig12_sweep")
    if not new:
        print("FAIL: new record has no fig12_sweep entry "
              "(did --only netsim_speedup run?)")
        return 1
    if not base:
        print("WARN: baseline has no fig12_sweep entry; gating stat-diff only")

    ok = True
    per_step = new["per_step_us_compact"]
    if base:
        limit = base["per_step_us_compact"] * (1.0 + args.max_regress)
        verdict = "OK" if per_step <= limit else "FAIL"
        ok &= per_step <= limit
        print(f"{verdict}: per_step_us_compact {per_step:.1f} "
              f"(baseline {base['per_step_us_compact']:.1f}, "
              f"limit {limit:.1f})")
        if per_step > limit:
            print("      note: the baseline is wall-clock from the machine "
                  "that committed BENCH_netsim.json; on unrelated/slower "
                  "hardware set REPRO_CI_SKIP_BENCH_GATE=1")

    diff = new["max_stat_diff_pct"]
    verdict = "OK" if diff <= args.max_stat_diff else "FAIL"
    ok &= diff <= args.max_stat_diff
    print(f"{verdict}: max_stat_diff_pct {diff:.4f} "
          f"(limit {args.max_stat_diff})")

    spill = new.get("spill_steps", 0)
    verdict = "OK" if spill == 0 else "FAIL"
    ok &= spill == 0
    print(f"{verdict}: spill_steps {spill}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
