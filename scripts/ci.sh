#!/usr/bin/env bash
# CI smoke: tier-1 test suite + one fast end-to-end paper bench.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# dist layer under a forced 8-device host platform: re-runs the planning /
# sharding / co-sim tests with the sweep runner actually sharding over 8
# local devices (the pmap-of-vmap dispatch path).  The subprocess-based
# collective tests pin their own child XLA_FLAGS, so rerunning them here
# would add compile minutes for zero new coverage — deselect them.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_collectives.py tests/test_system.py \
  tests/test_dist_extra.py -k "not equals_psum and not across_mesh_sizes"

# bench_fig10 fast mode: exercises trace generation, the sweep runner, the
# compact engine, and the metrics layer end to end in under a minute.
python -m benchmarks.run --only fig10 --json /tmp/BENCH_smoke.json

# perf regression gate: rerun the fig12 fast sweep (compact + dense oracle)
# and fail if the compact per-step cost regressed >30% vs the committed
# baseline, if the compact-vs-dense stat divergence exceeds 0.01%, or if
# the sweep spilled.  Skip with REPRO_CI_SKIP_BENCH_GATE=1 (e.g. on a
# machine unrelated to the committed baseline's).
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only netsim_speedup --json /tmp/BENCH_gate.json
  python scripts/check_bench.py /tmp/BENCH_gate.json BENCH_netsim.json
fi
