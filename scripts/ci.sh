#!/usr/bin/env bash
# CI smoke: tier-1 test suite + one fast end-to-end paper bench.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q --durations=15

# dist layer under a forced 8-device host platform: re-runs the planning /
# sharding / co-sim tests with the sweep runner actually sharding over 8
# local devices (the pmap-of-vmap dispatch path).  The subprocess-based
# collective tests pin their own child XLA_FLAGS, so rerunning them here
# would add compile minutes for zero new coverage — deselect them.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_collectives.py tests/test_system.py \
  tests/test_dist_extra.py -k "not equals_psum and not across_mesh_sizes"

# bench_fig10 fast mode: exercises trace generation, the sweep runner, the
# compact engine, and the metrics layer end to end in under a minute.
python -m benchmarks.run --only fig10 --json /tmp/BENCH_smoke.json

# 2-epoch co-sim smoke on the forced 8-device platform: the training-side
# plan -> fluid-sim -> quarantine -> plan loop (dist.cosim via launch.train
# --cosim-epochs), healthy fabric — just the loop plumbing, the sharded
# dispatch, and the traced-capacity compile reuse.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m repro.launch.train --cosim-epochs 2 --cosim-kill-spine -1 \
  --cosim-only

# perf regression gate: rerun the fig12 fast sweep (compact + dense oracle)
# and fail if the compact per-step cost regressed >30% vs the committed
# baseline, if the compact-vs-dense stat divergence exceeds 0.01%, or if
# the sweep spilled.  Skip with REPRO_CI_SKIP_BENCH_GATE=1 (e.g. on a
# machine unrelated to the committed baseline's).
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only netsim_speedup --json /tmp/BENCH_gate.json
  python scripts/check_bench.py /tmp/BENCH_gate.json BENCH_netsim.json
fi

# co-sim convergence gate: rerun the fast killed-spine scenarios and fail
# if any scenario's convergence-epoch count regressed by more than 1 vs
# the committed record, if one stopped converging, or if epochs after the
# first rebuilt sweep executables (the traced-capacity reuse contract).
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only cosim --json /tmp/BENCH_cosim.json
  python scripts/check_bench.py /tmp/BENCH_cosim.json BENCH_netsim.json \
    --cosim
fi

# chaos smoke on the forced 8-device platform: a seeded 3-fault random
# campaign (flap / lossy / straggler mix) runs end to end through the
# crash-proof pool — the driver must reconverge and salvage ZERO cells
# (a JobFailure here means a worker crashed, the one thing the chaos
# framework exists to make impossible).  The campaign spans the first 6
# epochs; the run gets 2 clean trailing epochs so BOTH schemes can
# reconverge (seqbalance sub-flows spray over every path, so it cannot
# dodge a fault that persists to the final epoch).
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF'
from repro.dist import cosim
from repro.netsim import faults, sweep, topology

topo = topology.leaf_spine(4, 4, 4, 100e9)
camp = faults.random_campaign(topo, seed=11, epochs=6, n_faults=3, n_ranks=8)
print("chaos smoke campaign:", *camp.summary(), sep="\n  ")
hists = cosim.run_cosim_grid(
    [dict(topo=topo, hosts=cosim.ring_hosts(topo, 8), size_bytes=4e6,
          scheme=s, epochs=8, phi_steps=2, cooldown_steps=2, n_chunks=4,
          seed=0, campaign=camp) for s in ("ecmp", "seqbalance")],
    salvage=True, retries=1)
crashed = [h for h in hists if h is None or getattr(h, "failed", False)]
assert not crashed, f"chaos smoke: {len(crashed)} crashed cells: {crashed}"
for h in hists:
    conv = h.convergence_epoch(1)
    assert conv is not None, f"{h.scheme}: no reconvergence after campaign"
    print(f"chaos smoke: {h.scheme} reconverged at epoch {conv}, "
          f"0 crashed cells")
EOF

# chaos-campaign gate: rerun the fast campaign bench and fail on crashed
# (salvaged) cells, lost reconvergence, or a >30% worst censored-p99
# regression vs the committed record.
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only faults --json /tmp/BENCH_faults.json
  python scripts/check_bench.py /tmp/BENCH_faults.json BENCH_netsim.json \
    --faults
fi

# degraded-telemetry smoke on the forced 8-device platform: the same
# killed-spine scenario with its congestion reports pushed through a
# seeded 30%-loss / 1-epoch-delay / duplicating channel — the planner
# must still quarantine the dead paths and reconverge, plan versions must
# stay strictly monotone (a replayed older plan is refused, never
# applied), and a full blackout must trip the safe-mode fallback and
# recover once the channel heals.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF2'
from repro.dist import cosim
from repro.netsim import faults, topology

topo = topology.leaf_spine(4, 4, 4, 100e9)
hosts = cosim.ring_hosts(topo, 8)
kw = dict(scheme="ecmp", epochs=8, phi_steps=2, n_chunks=4, seed=0,
          faults=(cosim.kill_spine(topo, 2, epoch=1, recover_epoch=5),))
h = cosim.run_cosim(topo, hosts, 4e6, staleness_bound=2,
                    telemetry=faults.TelemetryChannel(
                        loss=0.3, delay_epochs=1, dup=0.2, seed=7), **kw)
conv = h.convergence_epoch(1)
assert conv is not None, "lossy telemetry: no reconvergence"
vs = [r.plan_version for r in h.records]
assert all(b > a for a, b in zip(vs, vs[1:])), f"non-monotone plans: {vs}"
assert h.plan_refused == 0, f"{h.plan_refused} newer plans refused"
assert any(r.reported_slow for r in h.records), "no reports admitted"
print(f"telemetry smoke: lossy channel reconverged at epoch {conv}, "
      f"plan versions monotone, 0 refusals")
hb = cosim.run_cosim(topo, hosts, 4e6, blackout_epochs=2,
                     telemetry=faults.TelemetryChannel(blackout=(0, 4),
                                                       seed=1), **kw)
safe = [r.epoch for r in hb.records if r.safe_mode]
assert safe, "blackout never tripped safe mode"
assert not hb.records[-1].safe_mode, "never recovered from safe mode"
print(f"telemetry smoke: blackout safe-mode epochs {safe}, recovered")
EOF2

# degraded-telemetry gate: rerun the telemetry bench and fail on a broken
# perfect-channel bit-identity, unbounded lossy/delayed reconvergence,
# non-monotone plan versions, a blackout that misses safe mode, or a >1
# convergence-epoch regression vs the committed record.
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only telemetry --json /tmp/BENCH_telemetry.json
  python scripts/check_bench.py /tmp/BENCH_telemetry.json BENCH_netsim.json \
    --telemetry
fi

# adaptive-dt co-sim smoke on the forced 8-device platform: the killed-
# spine scenario with the event-driven adaptive engine enabled must
# reconverge at the same epoch as fixed dt with bit-identical FCT curves
# (the cosim ring is back-to-back, so every chunk holds an event and the
# quiescence predicate correctly never fires), must not rebuild any
# executable after epoch 0, and the sparse collective workload (compute
# gaps between rounds) must actually fast-forward with identical results.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF3'
import numpy as np
from repro.dist import cosim
from repro.netsim import sweep, topology, workloads
from repro.netsim.engine import SimConfig

topo = topology.leaf_spine(4, 4, 4, 100e9)
hosts = cosim.ring_hosts(topo, 8)
kw = dict(scheme="ecmp", epochs=6, phi_steps=2, n_chunks=4, seed=0,
          faults=(cosim.kill_spine(topo, 2, epoch=1, recover_epoch=4),))
h_f = cosim.run_cosim(topo, hosts, 4e6, **kw)
h_a = cosim.run_cosim(topo, hosts, 4e6, adaptive=True, **kw)
assert h_a.convergence_epoch(1) == h_f.convergence_epoch(1), (
    h_a.convergence_epoch(1), h_f.convergence_epoch(1))
p99_f = [r.fct_p99_s for r in h_f.records]
p99_a = [r.fct_p99_s for r in h_a.records]
assert p99_f == p99_a, "adaptive cosim diverged from fixed dt"
builds_late = sum(r.new_builds for r in h_a.records[1:])
assert builds_late == 0, f"{builds_late} rebuilds after epoch 0"
from repro.dist import collectives
plan = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
trace = workloads.collective_trace(plan, hosts, 4e6, link_bw=100e9,
                                   round_gap_s=800e-6, seed=0,
                                   steer_paths=topo.n_paths)
cfg = SimConfig(scheme="seqbalance", duration_s=14e-3,
                uplink_sample_every=10)
import dataclasses
res_f, _ = sweep.run_one(topo, cfg, trace)
res_a, _ = sweep.run_one(topo, dataclasses.replace(cfg, adaptive=True), trace)
assert res_a.ff_steps > 0, "sparse collective never fast-forwarded"
assert np.array_equal(np.asarray(res_f.finish), np.asarray(res_a.finish))
print(f"adaptive smoke: cosim reconverged at epoch "
      f"{h_a.convergence_epoch(1)} (p99 == fixed dt, 0 rebuilds), "
      f"collective ff {res_a.ff_steps} steps, finish times identical")
EOF3

# adaptive-dt gate: rerun the adaptive bench and fail on adaptive-vs-fixed
# stat divergence, a speedup below the committed floors (collective >= 2x,
# fig12 parity), a collective run that never fast-forwards, or any
# executable rebuild after the first adaptive dispatch.
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only adaptive --json /tmp/BENCH_adaptive.json
  python scripts/check_bench.py /tmp/BENCH_adaptive.json BENCH_netsim.json \
    --adaptive
fi

# observability smoke on the forced 8-device platform: a 2-epoch recorded
# co-sim must produce a schema-v2 flight log covering both epochs (with
# the in-sim ring-buffer drain on each), export to a perfetto-loadable
# Chrome trace, and round-trip through the [epoch, uplink, feature]
# matrix — while staying bit-identical to the unrecorded driver and
# building ZERO executables after epoch 0.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF4'
import json, os, tempfile
from repro import obs
from repro.dist import cosim
from repro.netsim import topology

topo = topology.leaf_spine(4, 4, 4, 100e9)
hosts = cosim.ring_hosts(topo, 8)
kw = dict(scheme="ecmp", epochs=2, phi_steps=2, n_chunks=4, seed=0,
          faults=(cosim.kill_spine(topo, 2, epoch=1),))
fd, fl = tempfile.mkstemp(suffix=".jsonl"); os.close(fd)
tr_path = fl + ".trace.json"
h0 = cosim.run_cosim(topo, hosts, 4e6, **kw)
h1 = cosim.run_cosim(topo, hosts, 4e6, record=obs.RecordSpec(ring_chunks=32),
                     flight=fl, **kw)
assert [r.fct_p99_s for r in h0.records] == [r.fct_p99_s for r in h1.records]
assert sum(r.new_builds for r in h1.records[1:]) == 0
header, recs = obs.read_flight(fl)
eps = [r for r in recs if r["kind"] == "epoch"]
assert len(eps) == 2 and all(r.get("insim") for r in eps), eps
from repro.obs import trace_export
from repro.obs.features import epoch_matrix
trace = trace_export.export_chrome_trace(fl, tr_path)
assert len(json.load(open(tr_path))["traceEvents"]) == len(trace["traceEvents"])
m = epoch_matrix((header, recs))
assert m["matrix"].shape == (2, topo.uplink_ids.size, len(m["features"]))
os.unlink(fl); os.unlink(tr_path)
print(f"obs smoke: 2-epoch flight log, {len(trace['traceEvents'])} trace "
      f"events, matrix {m['matrix'].shape}, driver bit-identical, 0 rebuilds")
EOF4

# observability gate: rerun the obs bench and fail if warm recording
# overhead exceeds the committed floor (5%), if the recorder rebuilt an
# executable after its first dispatch, or if the killed-agg-spine flight
# log missed an epoch / its in-sim drain.
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only obs --json /tmp/BENCH_obs.json
  python scripts/check_bench.py /tmp/BENCH_obs.json BENCH_netsim.json --obs
fi

# flowcell smoke on the forced 8-device platform: a flowcell-split plan
# (chunks sprayed over every active path) plus a live go-back-N reorder
# budget must run through the co-sim loop with ZERO executable rebuilds
# after epoch 0 (spray is a traced trace column, the budget a traced
# scalar operand — one compiled program covers every split factor and
# budget), and the degenerate settings (flowcells=1, budget unset) must
# leave the driver bit-identical to the classic path.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF5'
from repro.dist import cosim
from repro.netsim import topology

topo = topology.leaf_spine(4, 4, 4, 100e9)
hosts = cosim.ring_hosts(topo, 8)
kw = dict(scheme="seqbalance", epochs=3, phi_steps=2, n_chunks=4, seed=0,
          faults=(cosim.kill_spine(topo, 2, epoch=1),))
h_fc = cosim.run_cosim(topo, hosts, 4e6, flowcells=4, reorder_budget=16.0,
                       **kw)
builds_late = sum(r.new_builds for r in h_fc.records[1:])
assert builds_late == 0, f"{builds_late} rebuilds after epoch 0"
h0 = cosim.run_cosim(topo, hosts, 4e6, **kw)
h1 = cosim.run_cosim(topo, hosts, 4e6, flowcells=1, reorder_budget=None,
                     **kw)
assert [r.fct_p99_s for r in h0.records] == [r.fct_p99_s for r in h1.records]
print(f"flowcell smoke: 3-epoch co-sim with flowcells=4 / budget=16 MTU, "
      f"0 rebuilds after epoch 0, degenerate knobs bit-identical")
EOF5

# flowcell gate: rerun the flowcell bench and fail if spraying stops
# beating SeqBalance in the cost-free arm, stops losing at the strict
# go-back-N budget on the symmetric fabric (the paper's no-reordering
# motivation, quantified), if the hetero-fabric grid goes missing, if the
# co-sim rebuilt an executable after epoch 0, or if the degenerate arms'
# stat diff is not EXACTLY zero.
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only flowcell --json /tmp/BENCH_flowcell.json
  python scripts/check_bench.py /tmp/BENCH_flowcell.json BENCH_netsim.json \
    --flowcell
fi
