#!/usr/bin/env bash
# CI smoke: tier-1 test suite + one fast end-to-end paper bench.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# dist layer under a forced 8-device host platform: re-runs the planning /
# sharding / co-sim tests with the sweep runner actually sharding over 8
# local devices (the pmap-of-vmap dispatch path).  The subprocess-based
# collective tests pin their own child XLA_FLAGS, so rerunning them here
# would add compile minutes for zero new coverage — deselect them.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_collectives.py tests/test_system.py \
  tests/test_dist_extra.py -k "not equals_psum and not across_mesh_sizes"

# bench_fig10 fast mode: exercises trace generation, the sweep runner, the
# compact engine, and the metrics layer end to end in under a minute.
python -m benchmarks.run --only fig10 --json /tmp/BENCH_smoke.json

# 2-epoch co-sim smoke on the forced 8-device platform: the training-side
# plan -> fluid-sim -> quarantine -> plan loop (dist.cosim via launch.train
# --cosim-epochs), healthy fabric — just the loop plumbing, the sharded
# dispatch, and the traced-capacity compile reuse.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m repro.launch.train --cosim-epochs 2 --cosim-kill-spine -1 \
  --cosim-only

# perf regression gate: rerun the fig12 fast sweep (compact + dense oracle)
# and fail if the compact per-step cost regressed >30% vs the committed
# baseline, if the compact-vs-dense stat divergence exceeds 0.01%, or if
# the sweep spilled.  Skip with REPRO_CI_SKIP_BENCH_GATE=1 (e.g. on a
# machine unrelated to the committed baseline's).
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only netsim_speedup --json /tmp/BENCH_gate.json
  python scripts/check_bench.py /tmp/BENCH_gate.json BENCH_netsim.json
fi

# co-sim convergence gate: rerun the fast killed-spine scenarios and fail
# if any scenario's convergence-epoch count regressed by more than 1 vs
# the committed record, if one stopped converging, or if epochs after the
# first rebuilt sweep executables (the traced-capacity reuse contract).
if [ -z "${REPRO_CI_SKIP_BENCH_GATE:-}" ]; then
  python -m benchmarks.run --only cosim --json /tmp/BENCH_cosim.json
  python scripts/check_bench.py /tmp/BENCH_cosim.json BENCH_netsim.json \
    --cosim
fi
