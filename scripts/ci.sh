#!/usr/bin/env bash
# CI smoke: tier-1 test suite + one fast end-to-end paper bench.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# bench_fig10 fast mode: exercises trace generation, the sweep runner, the
# compact engine, and the metrics layer end to end in under a minute.
python -m benchmarks.run --only fig10 --json /tmp/BENCH_smoke.json
