#!/usr/bin/env python
"""Text dashboard for a co-sim flight log (DESIGN.md §16).

Reads the schema-v2 JSONL written by ``dist.cosim.run_cosim(flight=...)``
and prints the run at a glance: per-epoch FCT / plan churn / quarantine /
safe-mode / fast-forward table, the hottest uplinks across the run, fault
activations, telemetry verdict counters, and the sweep's build +
resilience totals.  Companion to the perfetto exporter
(``python -m repro.obs.trace_export``) for terminals without a browser.

    PYTHONPATH=src python scripts/obs_report.py flight.jsonl [--top 5]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))


def report(path: str, top: int = 5) -> int:
    from repro.obs import read_flight

    header, records = read_flight(path)
    epochs = [r for r in records if r.get("kind") == "epoch"]
    camp = next((r for r in records if r.get("kind") == "campaign"), {})
    end = next((r for r in records if r.get("kind") == "run_end"), {})

    rm = header.get("runmeta") or {}
    print(f"flight {path}")
    print(f"  run {header.get('run_id', '?')}  git {rm.get('git_sha', '?')}"
          f"  host {rm.get('host', '?')}  devices {rm.get('n_devices', '?')}")
    print(f"  scheme {camp.get('scheme', '?')}  epochs "
          f"{len(epochs)}/{camp.get('epochs', '?')}  "
          f"n_steps {camp.get('n_steps', '?')}  "
          f"faults {camp.get('n_faults', 0)}")
    if not epochs:
        print("  (no epoch records)")
        return 1

    print(f"\n  {'ep':>3} {'p99_us':>10} {'compl':>6} {'churn':>5} "
          f"{'quar':>8} {'safe':>4} {'ff%':>5} {'builds':>6} {'faults':>16}")
    for r in epochs:
        ins = r.get("insim") or {}
        n_steps = r.get("n_steps") or 0
        ffpct = 100.0 * ins.get("ff_steps", 0) / n_steps if n_steps else 0.0
        faults = ",".join(f.get("kind", "?") for f in r.get("faults") or ())
        print(f"  {r.get('epoch', -1):>3} {r.get('fct_p99_us', 0):>10.1f} "
              f"{r.get('completion', 0):>6.3f} {r.get('plan_churn', 0):>5} "
              f"{str(r.get('quarantined') or '-'):>8} "
              f"{'Y' if r.get('safe_mode') else '.':>4} {ffpct:>5.1f} "
              f"{r.get('new_builds', 0):>6} {faults or '-':>16}")

    # hottest uplinks across the whole run (max util per (leaf, uplink))
    hot: dict[tuple, dict] = {}
    for r in epochs:
        for h in r.get("hot_uplinks") or ():
            k = (h.get("leaf"), h.get("uplink"))
            if k not in hot or h["util"] > hot[k]["util"]:
                hot[k] = h
    if hot:
        print(f"\n  hottest uplinks (top {top}, max over epochs):")
        for h in sorted(hot.values(), key=lambda d: -d["util"])[:top]:
            print(f"    leaf {h['leaf']} uplink {h['uplink']} "
                  f"(link {h['link']}): util {h['util']:.3f}  "
                  f"offered {h['offered_gbps']:.2f} Gb/s")

    last = epochs[-1]
    wd = last.get("watchdog") or {}
    if wd:
        t = wd.get("transitions") or {}
        print(f"\n  watchdog: silent {wd.get('silent')} safe "
              f"{wd.get('safe')}  transitions "
              + " ".join(f"{k}={t.get(k, 0)}" for k in
                         ("ok", "silent", "safe", "recovered")))
    sw = (end.get("sweep") or last.get("sweep")) or {}
    if sw:
        print("  sweep: " + "  ".join(f"{k} {v}" for k, v in sw.items()))
    if end:
        print(f"  run_end: convergence_epoch {end.get('convergence_epoch')}"
              f"  plan_refused {end.get('plan_refused')}  total_new_builds "
              f"{end.get('total_new_builds')}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="text dashboard for a cosim flight log")
    ap.add_argument("flight", help="flight-log JSONL path")
    ap.add_argument("--top", type=int, default=5,
                    help="hottest-uplink rows to show")
    args = ap.parse_args(argv)
    return report(args.flight, top=args.top)


if __name__ == "__main__":
    sys.exit(main())
