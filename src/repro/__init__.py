"""repro — SeqBalance (RoCE load balancing) in JAX, plus the multi-pod
training/serving framework that embeds it as a first-class grad-sync and
collective-scheduling feature.  See DESIGN.md for the system inventory."""
__version__ = "0.1.0"
