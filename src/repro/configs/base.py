"""Model / run configuration dataclasses.

One ``ModelConfig`` covers the whole assigned-architecture pool; per-arch
files in this package instantiate it with the published numbers.  A config
is STATIC (hashable) so it can parameterize jitted programs.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["attn", "mlstm", "slstm", "rglru"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0  # routed experts
    top_k: int = 0
    n_shared: int = 0  # shared (always-on) experts
    d_expert: int = 0  # per-expert FFN width
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 32000
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention variants
    qk_norm: bool = False  # qwen3
    logit_softcap: float = 0.0  # gemma2 (30.0 final / 50.0 attn)
    attn_softcap: float = 0.0
    local_window: int = 0  # sliding-window size where used
    local_global_alternate: bool = False  # gemma2: even layers local
    rope_theta: float = 10000.0

    # --- MLP variants
    mlp_kind: str = "gated_silu"  # gated_silu | gated_gelu | squared_relu
    moe: MoEConfig = MoEConfig()

    # --- recurrent variants
    layer_pattern: tuple[str, ...] = ()  # superblock, e.g. ("rglru","rglru","attn")
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333
    rglru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4  # temporal conv in recurrent blocks

    # --- encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_frames: int = 1500  # stub audio frontend sequence length

    # --- multimodal stub (internvl)
    n_vision_tokens: int = 0  # patch embeddings prepended by the stub

    # --- numerics / training
    q_chunk: int = 1024  # q-block size for chunked long-seq attention
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "none"  # none | dots | full  (per-superblock policy)
    tie_embeddings: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        """The repeating superblock of layer kinds."""
        if self.layer_pattern:
            return self.layer_pattern
        return ("attn",)

    def superblocks(self) -> tuple[int, tuple[str, ...]]:
        """(n_repeats, pattern); n_layers must be divisible by len(pattern)
        except for an optional trailing partial block handled by the stack."""
        p = self.pattern
        return self.n_layers // len(p), p

    @property
    def trailing(self) -> tuple[str, ...]:
        p = self.pattern
        r = self.n_layers % len(p)
        return p[:r]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)
