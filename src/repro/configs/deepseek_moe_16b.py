"""deepseek-moe-16b [moe]: fine-grained MoE — 2 shared + 64 routed experts,
top-6, expert width 1408.  [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, mlp_kind="gated_silu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=32, vocab=256,
                         moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32))
