"""gemma2-2b [dense]: local(4096)/global alternating attention, logit
softcap 30 / attn softcap 50, GQA kv=4, head_dim 256.  [arXiv:2408.00118]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256, mlp_kind="gated_gelu",
    local_global_alternate=True, local_window=4096,
    logit_softcap=30.0, attn_softcap=50.0,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab=256, local_window=8)
