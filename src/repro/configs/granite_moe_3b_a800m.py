"""granite-moe-3b-a800m [moe]: 40 routed experts top-8, expert width 512
(assignment spec; the hf granite-3.0-3b-a800m twin ships 40 experts top-8).
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, mlp_kind="gated_silu",
    moe=MoEConfig(n_experts=40, top_k=8, n_shared=0, d_expert=512),
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=32, vocab=256,
                         moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_expert=32))
