"""internvl2-26b [vlm]: InternViT frontend stubbed to 256 patch embeddings
prepended to the text sequence; InternLM2-20B-style backbone.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, mlp_kind="gated_silu", n_vision_tokens=256,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=256, n_vision_tokens=8)
