"""nemotron-4-15b [dense]: squared-ReLU MLP (ungated), GQA kv=8.
[arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab=256000, mlp_kind="squared_relu",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=256)
