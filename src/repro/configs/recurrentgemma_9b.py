"""recurrentgemma-9b [hybrid]: RG-LRU + local attention (window 2048) at
2:1, MQA kv=1, MLP after every mixer.  38 layers = 12x(R,R,A) + (R,R).
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, mlp_kind="gated_gelu",
    local_window=2048, rglru_width=4096,
)

REDUCED = CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
                         head_dim=16, d_ff=128, vocab=256, local_window=8,
                         rglru_width=64)
