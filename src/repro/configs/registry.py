"""Architecture registry + per-cell input specs (ShapeDtypeStruct only —
the full configs are exercised exclusively through the dry-run)."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeCell

ARCH_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-1.3b": "xlstm_1_3b",
    "gemma2-2b": "gemma2_2b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-32b": "qwen3_32b",
    "nemotron-4-15b": "nemotron_4_15b",
    "internvl2-26b": "internvl2_26b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "recurrentgemma-9b": "recurrentgemma_9b",
}
ARCHS = tuple(ARCH_MODULES)

# long_500k needs sub-quadratic attention over the whole context; only the
# SSM/hybrid archs hold O(1)/O(window) state (DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("xlstm-1.3b", "recurrentgemma-9b")


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(name: str) -> ShapeCell:
    (cell,) = [s for s in SHAPES if s.name == name]
    return cell


def cell_is_supported(arch: str, shape: ShapeCell) -> tuple[bool, str]:
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "full-attention arch: 500k-context decode is quadratic-regime (skipped per assignment)"
    return True, ""


def list_cells(include_skipped: bool = False):
    """All (arch, shape) cells; skipped ones annotated."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_is_supported(arch, shape)
            if ok or include_skipped:
                out.append((arch, shape, ok, why))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCell, *, batch_override: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {tokens, labels?} (+frames / vision_embeds stubs).
    decode: {tokens[B,1]} — the KV cache spec comes from
    ``jax.eval_shape(model.init_cache, ...)`` in the launcher.
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32)}
        return batch
    batch = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = sds((B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = sds((B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch
