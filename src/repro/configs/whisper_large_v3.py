"""whisper-large-v3 [audio]: enc-dec, conv/mel frontend stubbed to frame
embeddings (input_specs feeds [B, 1500, d_model]).  32 enc + 32 dec layers.
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, mlp_kind="gelu",
    is_encoder_decoder=True, n_encoder_layers=32, encoder_frames=1500,
)

REDUCED = CONFIG.replace(n_layers=2, n_encoder_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                         encoder_frames=24)
