"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks at 7:1, no separate FFN (d_ff=0).
[arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
)

REDUCED = CONFIG.replace(n_layers=8, d_model=64, n_heads=2, vocab=256)
