"""SeqBalance core: the paper's contribution as composable JAX modules.

  hashing          five-tuple hashing + double-hash probe sequences
  shaper           WQE -> N sub-WQEs, per-sub-flow QPs, bitmap CQE
  congestion_table phi-expiring inactive-path table (source ToR)
  routing          first-packet path selection with congested-path rehash
  baselines        ECMP / LetFlow / CONGA / DRILL policies
  gbn              go-back-N retransmission cost model
"""
from repro.core import baselines, congestion_table, gbn, hashing, routing, shaper

__all__ = ["baselines", "congestion_table", "gbn", "hashing", "routing", "shaper"]
