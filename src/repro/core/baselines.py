"""Baseline load-balancing policies the paper compares against (§IV.B).

Each policy is a pure function mapping (flow state, link state, rng) ->
path choice, consumed by the netsim engine.  The *fluid-model* renderings
of the packet-level schemes are documented inline and in DESIGN.md §8.

  ECMP    — per-flow five-tuple hash, static (the deployed default).
  LetFlow — flowlet switching: when an inter-packet gap exceeds the flowlet
            timeout, the next burst re-draws a RANDOM path.  In fluid form a
            gap occurs iff the flow's packet interval MTU/rate exceeds the
            timeout — which for RDMA's continuous high-rate traffic almost
            never happens (paper Fig. 1: RDMA flowlets are GB-sized).
  CONGA   — flowlet switching, but the new path is the argmin of a
            congestion metric (leaf-to-leaf, fed back in-band).  Same
            flowlet-starvation problem under RDMA.
  DRILL   — per-packet micro load balancing on local queue depths
            (power-of-two-choices).  Fluid form: each step a flow's traffic
            re-splits toward the shortest local queues; near-perfect
            balance, but the per-packet spray reorders packets and RDMA's
            go-back-N turns that into retransmission storms (core/gbn.py
            supplies the goodput penalty).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing


def flowlet_gap_occurs(rate_bps: jax.Array, mtu_bytes: float, timeout_s: float) -> jax.Array:
    """Fluid flowlet criterion: the inter-packet gap of a flow sending at
    ``rate`` is MTU/rate; a flowlet boundary appears iff that gap exceeds
    the inactivity timeout.  (rate<=0 counts as a boundary.)"""
    rate = jnp.maximum(rate_bps, 1e-9)
    gap = (mtu_bytes * 8.0) / rate
    return (gap > timeout_s) | (rate_bps <= 0.0)


def letflow_paths(
    cur_paths: jax.Array, gap: jax.Array, rng_u32: jax.Array, n_paths: int
) -> jax.Array:
    """LetFlow: keep the current path unless a flowlet gap occurred, in which
    case pick uniformly at random (rng_u32: independent uint32 per flow)."""
    rand_path = (rng_u32 % jnp.uint32(n_paths)).astype(jnp.int32)
    return jnp.where(gap, rand_path, cur_paths)


def flowlet_wcmp_paths(
    cur_paths: jax.Array, gap: jax.Array, rng_u32: jax.Array, weights: jax.Array
) -> jax.Array:
    """Flowlet-timeout controller with CAPACITY-WEIGHTED re-draws (the
    asymmetric-topology variant of the Harvard CS145 flowlet controller):
    keep the current path unless a flowlet gap occurred, in which case draw
    the next path from the WCMP distribution ``weights`` (f32[n_paths],
    summing to 1) via cumulative-weight inversion of the per-flow uniform
    ``rng_u32 / 2^32``.  On a symmetric fabric the weights are uniform and
    this degenerates to ``letflow_paths``; on a mixed 100G/400G fabric the
    fat uplinks absorb proportionally more flowlets — the fix the plain
    random re-draw lacks."""
    n_paths = weights.shape[-1]
    cum = jnp.cumsum(weights, axis=-1)  # [..., P], last entry ~1.0
    u = rng_u32.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    pick = jnp.sum((u[..., None] >= cum).astype(jnp.int32), axis=-1)
    pick = jnp.clip(pick, 0, n_paths - 1).astype(jnp.int32)
    return jnp.where(gap, pick, cur_paths)


def conga_paths(
    cur_paths: jax.Array, gap: jax.Array, path_congestion: jax.Array
) -> jax.Array:
    """CONGA: on a flowlet boundary move to the least-congested path.

    path_congestion: f32[..., n_paths] — per-flow view of end-to-end path
    congestion (max of per-hop utilization, as CONGA's DRE measures)."""
    best = jnp.argmin(path_congestion, axis=-1).astype(jnp.int32)
    return jnp.where(gap, best, cur_paths)


def drill_weights(queue_bytes: jax.Array, q0: float = 1500.0) -> jax.Array:
    """DRILL fluid split: fraction of a flow's packets sent to each path
    this step.  DRILL sends every packet to the shortest of (2 random + the
    last-best) local queues; in expectation traffic concentrates on short
    queues, which we render as inverse-queue-proportional weights.

    queue_bytes: f32[..., n_paths] -> weights summing to 1 along last axis.
    """
    inv = 1.0 / (queue_bytes + q0)
    return inv / jnp.sum(inv, axis=-1, keepdims=True)


def wcmp_weights(capacity_bps: jax.Array) -> jax.Array:
    """Capacity-proportional static weights (used for ideal/asymmetric
    baselines and sanity checks)."""
    return capacity_bps / jnp.sum(capacity_bps, axis=-1, keepdims=True)
