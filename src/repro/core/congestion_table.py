"""Congestion Table at the source ToR switch (paper §III.A/§III.B/§III.D).

The destination ToR mirrors any ECN-marked data packet back to the source
ToR as a *Congestion Packet* whose 10-bit BTH PathTag names the congested
path.  On receipt, the source ToR marks that path *inactive* for a duration
phi; further Congestion Packets for the same path REFRESH the timer.  A path
sheds its inactive status only after phi elapses with no new Congestion
Packet.  Inactive paths reject NEW sub-flows (they keep carrying already
-placed sub-flows — rerouting mid-flow would reorder packets).

Representation: ``inactive_until[tor, path]`` — absolute simulation time
until which the path is closed to new sub-flows.  Refresh == scatter-max of
(now + phi), which is exactly the paper's restart-the-timer semantics and is
a single vectorized op per step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CongestionTable(NamedTuple):
    inactive_until: jax.Array  # f32[n_tors, n_paths] absolute time

    @classmethod
    def create(cls, n_tors: int, n_paths: int) -> "CongestionTable":
        return cls(inactive_until=jnp.full((n_tors, n_paths), -jnp.inf, jnp.float32))


def mark_congested(
    table: CongestionTable,
    tor_ids: jax.Array,
    path_ids: jax.Array,
    now: jax.Array | float,
    phi: float,
    valid: jax.Array | None = None,
) -> CongestionTable:
    """Process a batch of Congestion Packets.

    tor_ids/path_ids: int32[k] (the source ToR that receives the packet and
    the PathTag it carries).  ``valid`` masks out padding entries.  Refresh
    semantics = scatter-max of (now + phi).
    """
    expiry = jnp.asarray(now, jnp.float32) + jnp.float32(phi)
    expiry = jnp.broadcast_to(expiry, jnp.shape(tor_ids))
    if valid is not None:
        expiry = jnp.where(valid, expiry, -jnp.inf)
    new = table.inactive_until.at[tor_ids, path_ids].max(expiry, mode="drop")
    return table._replace(inactive_until=new)


def mark_congested_dense(
    table: CongestionTable, congested_now: jax.Array, now: jax.Array | float, phi: float
) -> CongestionTable:
    """Dense variant: congested_now is bool[n_tors, n_paths] — which (tor,
    path) pairs received a Congestion Packet during this step.  This is the
    netsim fast path (no gather/scatter)."""
    expiry = jnp.where(congested_now, jnp.asarray(now, jnp.float32) + jnp.float32(phi), -jnp.inf)
    return table._replace(inactive_until=jnp.maximum(table.inactive_until, expiry))


def is_inactive(
    table: CongestionTable, tor_ids: jax.Array, path_ids: jax.Array, now: jax.Array | float
) -> jax.Array:
    """Is (tor, path) currently closed to new sub-flows?"""
    return jnp.asarray(now, jnp.float32) < table.inactive_until[tor_ids, path_ids]


def inactive_row(table: CongestionTable, tor_id: jax.Array, now: jax.Array | float) -> jax.Array:
    """bool[n_paths] inactive mask for one source ToR."""
    return jnp.asarray(now, jnp.float32) < table.inactive_until[tor_id]


def inactive_matrix(table: CongestionTable, now: jax.Array | float) -> jax.Array:
    """bool[n_tors, n_paths] — full inactive view at time ``now``."""
    return jnp.asarray(now, jnp.float32) < table.inactive_until


def occupancy(table: CongestionTable, now: jax.Array | float) -> jax.Array:
    """Number of currently-inactive paths per ToR (switch-memory footprint —
    the paper argues this stays tiny; we expose it so tests/benches can
    check)."""
    return inactive_matrix(table, now).sum(axis=-1)
