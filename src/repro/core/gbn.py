"""Go-back-N retransmission model (paper §II.A, Table I).

RDMA RNICs track a single expected PSN per QP.  Any out-of-order arrival
triggers a NAK (or, if NAKs are lost/suppressed, a timeout) and the sender
REWINDS to the missing PSN, retransmitting everything after it.  The paper
demonstrates (Table I) that delaying ONE packet inflates FCT by >=3x.

Two uses:
  * ``fct_with_one_delayed_packet`` — analytic reproduction of Table I.
  * ``gbn_goodput_factor``          — steady-state goodput multiplier for
    schemes that spray packets of one QP across unequal-latency paths
    (DRILL); consumed by the netsim engine as DRILL's penalty.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ideal_fct(size_bytes, rate_bps, base_rtt_s, mtu_bytes: float = 1000.0):
    """FCT of an uninterrupted transfer: serialization + one propagation."""
    size_bytes = jnp.asarray(size_bytes, jnp.float32)
    return size_bytes * 8.0 / rate_bps + base_rtt_s


def fct_with_one_delayed_packet(
    size_bytes,
    rate_bps,
    base_rtt_s,
    delayed_frac,
    nak_timeout_s,
    recovery_rate_frac: float = 0.25,
    mtu_bytes: float = 1000.0,
):
    """FCT when the packet at position ``delayed_frac``∈[0,1) of the flow is
    delayed long enough to arrive out of order.

    Timeline (go-back-N):
      t0 = delayed_frac*size/rate      : the hole appears at the receiver.
      receiver NAKs on the next arrival; sender learns after ~RTT, but
      commercial RNICs coalesce NAKs / rate-limit retransmit, rendering an
      effective recovery stall ``nak_timeout_s`` (micro-benchmarks on CX-6
      put this in the 10s-100s of us — far above the us-scale RTT, which is
      why Table I's small flows suffer a LARGER multiple than big flows: the
      stall is fixed, the flow is short).
      After the stall the sender rewinds to the hole and re-sends the rest of
      the flow — but the retransmission event also made DCQCN slash the QP
      rate (treated like a congestion event), so the re-send proceeds at
      ``recovery_rate_frac``·rate (two back-to-back halvings ≈ 0.25).
    """
    size_bytes = jnp.asarray(size_bytes, jnp.float32)
    t_serial = size_bytes * 8.0 / rate_bps
    t_to_hole = delayed_frac * t_serial
    t_resend = (1.0 - delayed_frac) * t_serial / recovery_rate_frac
    return t_to_hole + nak_timeout_s + t_resend + base_rtt_s


def table1_inflation(
    size_bytes,
    rate_bps=40e9,
    base_rtt_s=8e-6,
    delayed_frac=0.5,
    nak_timeout_s=80e-6,
    recovery_rate_frac=0.25,
):
    """FCT(delayed)/FCT(ideal) — the Table I ratio.

    Calibration (40 Gbps, 8 us RTT, mid-flow hole, 80 us NAK turnaround,
    rate cut to 1/4 during recovery):  64 KB -> 5.77x (paper: 5.77x avg),
    1 MB -> 2.83x (paper: 3.01x avg) — the fixed recovery stall dominating
    short flows is exactly the paper's "minimum threefold increase".
    """
    return fct_with_one_delayed_packet(
        size_bytes, rate_bps, base_rtt_s, delayed_frac, nak_timeout_s, recovery_rate_frac
    ) / ideal_fct(size_bytes, rate_bps, base_rtt_s)


def ooo_probability(
    path_delay_spread_s: jax.Array, rate_bps: jax.Array, mtu_bytes: float = 1000.0
) -> jax.Array:
    """Probability that a sprayed packet lands out of order.

    If consecutive packets of one QP ride paths whose one-way delays differ
    by more than one packet-serialization time, they swap on arrival.  With
    inter-packet spacing dt = MTU*8/rate, roughly min(1, spread/dt) of
    packets overtake a predecessor.
    """
    dt = mtu_bytes * 8.0 / jnp.maximum(rate_bps, 1.0)
    return jnp.clip(path_delay_spread_s / jnp.maximum(dt, 1e-12), 0.0, 1.0)


def gbn_goodput_factor(p_ooo: jax.Array, window_pkts: float = 64.0) -> jax.Array:
    """Steady-state goodput multiplier under go-back-N with per-packet OOO
    probability ``p_ooo``: every OOO event wastes ~window/2 packet slots
    (everything in flight past the hole is retransmitted).

      goodput = useful / (useful + wasted) = 1 / (1 + p_ooo * W/2)

    For DRILL under RDMA (p_ooo -> O(0.1..1)) this collapses goodput — the
    paper's observation that DRILL's FCT is "much higher than the other four
    algorithms" and partly off the chart.
    """
    return 1.0 / (1.0 + p_ooo * (window_pkts / 2.0))
