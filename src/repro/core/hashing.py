"""Deterministic five-tuple hashing, vectorized.

SeqBalance's source ToR hashes the *first packet* of every sub-flow on its
five-tuple to pick an uplink/path (paper §III.B).  Sub-flows of the same WQE
differ in their QP number (the Shaper gives each sub-WQE its own QP), so the
five-tuples differ and the sub-flows spread across paths — this is exactly
the "entropy multiplication" the paper describes for AI-training traffic.

We implement a murmur3-style 32-bit finalizer.  Everything is uint32 and
fully vectorized so the netsim engine can hash millions of sub-flows per
step inside jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_C1 = jnp.uint32(0xCC9E2D51)
_C2 = jnp.uint32(0x1B873593)
_F1 = jnp.uint32(0x85EBCA6B)
_F2 = jnp.uint32(0xC2B2AE35)


def _rotl32(x: jax.Array, r: int) -> jax.Array:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def fmix32(h: jax.Array) -> jax.Array:
    """murmur3 finalizer: avalanche a uint32."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * _F1
    h = h ^ (h >> jnp.uint32(13))
    h = h * _F2
    h = h ^ (h >> jnp.uint32(16))
    return h


def _mix_word(h: jax.Array, k: jax.Array) -> jax.Array:
    k = k.astype(jnp.uint32) * _C1
    k = _rotl32(k, 15)
    k = k * _C2
    h = h ^ k
    h = _rotl32(h, 13)
    return h * jnp.uint32(5) + jnp.uint32(0xE6546B64)


def hash_five_tuple(
    src: jax.Array,
    dst: jax.Array,
    sport: jax.Array,
    dport: jax.Array,
    salt: jax.Array | int = 0,
) -> jax.Array:
    """Vectorized five-tuple hash -> uint32.

    ``salt`` distinguishes independent hash functions (h1 vs h2 for the
    double-hashing probe sequence, or per-switch seeds).
    """
    h = jnp.uint32(salt) * jnp.uint32(0x9E3779B9) + jnp.uint32(0x2545F491)
    h = jnp.broadcast_to(h, jnp.broadcast_shapes(jnp.shape(src), jnp.shape(dst)))
    h = _mix_word(h, jnp.asarray(src))
    h = _mix_word(h, jnp.asarray(dst))
    h = _mix_word(h, jnp.asarray(sport))
    h = _mix_word(h, jnp.asarray(dport))
    return fmix32(h ^ jnp.uint32(4 * 4))


def double_hash_sequence(h1: jax.Array, h2: jax.Array, n_probes: int, n_paths: int) -> jax.Array:
    """Probe sequence path_i = (h1 + i * (2*h2+1)) mod n_paths.

    The 2*h2+1 forces an odd stride so the probe sequence visits every path
    when n_paths is a power of two (classic open-addressing trick); for
    non-power-of-two path counts it still cycles well.  Shape: [..., n_probes].
    """
    i = jnp.arange(n_probes, dtype=jnp.uint32)
    stride = (h2.astype(jnp.uint32) * jnp.uint32(2) + jnp.uint32(1))[..., None]
    seq = h1.astype(jnp.uint32)[..., None] + i * stride
    return (seq % jnp.uint32(n_paths)).astype(jnp.int32)
