"""Source-ToR routing decision (paper §III.B).

Only the FIRST packet of a sub-flow is routed: it is hashed on its
five-tuple to a candidate path; if the Congestion Table marks that path
inactive, the hash is re-iterated (double hashing) until an active path is
found; if every path is inactive the original hash choice is used (the
paper: an inactive path still carries its in-flight sub-flows, it only
"restricts the entry of new flows" — when there is no alternative the flow
must enter somewhere).  All subsequent packets stick to the chosen path, so
a sub-flow's packets can never be reordered by the fabric split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing


def select_paths(
    src: jax.Array,
    dst: jax.Array,
    sport: jax.Array,
    dport: jax.Array,
    inactive: jax.Array,
    n_paths: int,
    max_probes: int | None = None,
    salt: int = 0,
) -> jax.Array:
    """Vectorized SeqBalance path selection for a batch of new sub-flows.

    inactive: bool[..., n_paths] — the source ToR's current inactive mask
    for each sub-flow (rows already gathered per sub-flow's source ToR).
    Returns int32[...] chosen path ids.
    """
    if max_probes is None:
        max_probes = n_paths
    h1 = hashing.hash_five_tuple(src, dst, sport, dport, salt=salt)
    h2 = hashing.hash_five_tuple(src, dst, sport, dport, salt=salt + 0x5EED)
    probes = hashing.double_hash_sequence(h1, h2, max_probes, n_paths)  # [..., P]
    probe_inactive = jnp.take_along_axis(inactive, probes, axis=-1)  # [..., P]
    # index of first ACTIVE probe; if none, fall back to probe 0 (= plain hash)
    first_active = jnp.argmax(~probe_inactive, axis=-1)
    any_active = jnp.any(~probe_inactive, axis=-1)
    pick = jnp.where(any_active, first_active, 0)
    return jnp.take_along_axis(probes, pick[..., None], axis=-1)[..., 0]


def ecmp_paths(
    src: jax.Array, dst: jax.Array, sport: jax.Array, dport: jax.Array,
    n_paths: int, salt: int = 0,
) -> jax.Array:
    """Plain ECMP: hash once, no congestion awareness (baseline)."""
    h1 = hashing.hash_five_tuple(src, dst, sport, dport, salt=salt)
    return (h1 % jnp.uint32(n_paths)).astype(jnp.int32)
