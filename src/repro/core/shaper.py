"""SeqBalance Shaper (paper §III.C) — WQE segmentation + bitmap CQE (§III.D).

The Shaper lives in the RDMA driver: it splits one application WQE (a large
message) into N sub-WQEs of (near-)equal size, posts each on its OWN queue
pair (so each sub-flow has an independent PSN space and can safely take a
different network path), and raises a single CQE to the application only
after the ACKs of ALL sub-WQEs have arrived, tracked with a bitmap.

Everything here is a pure function over arrays so the netsim engine and the
dist-layer grad-sync engine can reuse the identical logic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing

MAX_SUBFLOWS = 32  # bitmap is uint32; the paper operates at N<=6


def split_wqe(size: jax.Array, n: int) -> jax.Array:
    """Split message sizes into n near-equal sub-WQE sizes.

    size: [...] integer/float byte counts.  Returns [..., n] with
    sum == size and max-min <= 1 (for integer sizes).  The paper splits into
    "N sub-flows of equal size"; with arbitrary byte counts the remainder
    bytes go to the first (size % n) sub-WQEs.
    """
    size = jnp.asarray(size)
    if size.dtype.kind in "iu":
        base = size[..., None] // n
        rem = size[..., None] % n
        bump = (jnp.arange(n) < rem).astype(size.dtype)
        return base + bump
    # float sizes (fluid model): exact equal split
    return jnp.broadcast_to(size[..., None] / n, size.shape + (n,))


def subflow_five_tuples(
    src: jax.Array, dst: jax.Array, flow_id: jax.Array, n: int, base_qpn: int = 0x1000
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Five-tuples for the n sub-flows of each WQE.

    Each sub-WQE is posted on its own QP; in RoCEv2 the UDP source port is
    derived from the QPN, so sub-flows hash differently at the ToR.  Returns
    (src, dst, sport, dport) each of shape [..., n].
    """
    sub = jnp.arange(n, dtype=jnp.uint32)
    qpn = (jnp.asarray(flow_id, jnp.uint32)[..., None] * jnp.uint32(n)
           + sub + jnp.uint32(base_qpn))
    sport = jnp.uint32(0xC000) + (hashing.fmix32(qpn) % jnp.uint32(0x3FFF))
    dport = jnp.broadcast_to(jnp.uint32(4791), sport.shape)  # RoCEv2 UDP port
    srcb = jnp.broadcast_to(jnp.asarray(src, jnp.uint32)[..., None], sport.shape)
    dstb = jnp.broadcast_to(jnp.asarray(dst, jnp.uint32)[..., None], sport.shape)
    return srcb, dstb, sport, dport


class CQEState(NamedTuple):
    """Sender-side completion tracking (paper Fig. 5).

    bitmap: uint32[...]  bit i set  <=>  ACK of sub-WQE i received.
    n_sub:  int32[...]   how many sub-WQEs the WQE was split into.
    """

    bitmap: jax.Array
    n_sub: jax.Array

    @classmethod
    def create(cls, n_wqes: int, n_sub: int | jax.Array) -> "CQEState":
        return cls(
            bitmap=jnp.zeros((n_wqes,), jnp.uint32),
            n_sub=jnp.broadcast_to(jnp.asarray(n_sub, jnp.int32), (n_wqes,)),
        )


def ack_subwqe(state: CQEState, wqe_idx: jax.Array, sub_idx: jax.Array) -> CQEState:
    """Record ACK arrival for (wqe, sub) pairs. Idempotent (bitwise OR)."""
    bit = jnp.uint32(1) << jnp.asarray(sub_idx, jnp.uint32)
    new_bitmap = state.bitmap.at[wqe_idx].set(state.bitmap[wqe_idx] | bit)
    return state._replace(bitmap=new_bitmap)


def ack_mask(state: CQEState, acked: jax.Array) -> CQEState:
    """Vectorized ACK: ``acked`` is bool[..., n] per-sub-flow arrivals this
    step; ORs the corresponding bits in one shot (netsim fast path)."""
    n = acked.shape[-1]
    bits = (acked.astype(jnp.uint32) << jnp.arange(n, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32
    )
    return state._replace(bitmap=state.bitmap | bits)


def cqe_ready(state: CQEState) -> jax.Array:
    """True where every sub-WQE has been ACKed -> the driver may raise the
    application-visible CQE (the app never sees the segmentation)."""
    full = jnp.where(
        state.n_sub >= 32,
        jnp.uint32(0xFFFFFFFF),
        (jnp.uint32(1) << state.n_sub.astype(jnp.uint32)) - jnp.uint32(1),
    )
    return (state.bitmap & full) == full


def popcount32(x: jax.Array) -> jax.Array:
    """Number of ACKs received (bit population count, uint32)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
