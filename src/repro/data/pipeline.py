"""Deterministic synthetic data pipeline with O(1) skip-ahead.

Batches are a pure function of (seed, step, position) — a restart at step N
resumes the exact token stream with no state replay (the property a
1000-node checkpoint/restart loop needs).  Sharding: each DP rank carves
its slice from the global batch by rank offset; the same function lowers
under pjit with the batch dimension sharded.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # synthetic structure: token t+1 = f(token t) with noise -> nonzero
    # learnable signal so loss decreases measurably in examples/train runs
    copy_prob: float = 0.9


def batch_at(cfg: DataConfig, step) -> dict:
    """Global batch for ``step``: {tokens, labels} of [B, S+? int32]."""
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    b = jnp.arange(B, dtype=jnp.uint32)[:, None]
    s = jnp.arange(S + 1, dtype=jnp.uint32)[None, :]
    base = hashing.fmix32(
        b * jnp.uint32(0x9E3779B9)
        ^ jnp.uint32(cfg.seed) * jnp.uint32(0x85EBCA6B)
        ^ jnp.uint32(step) * jnp.uint32(0xC2B2AE35)
    )
    noise = hashing.fmix32(base ^ s * jnp.uint32(0x27D4EB2F))
    # Markov-ish stream: mostly a deterministic walk, sometimes a jump
    walk = (base + s * jnp.uint32(7)) % jnp.uint32(max(V - 1, 1))
    jump = noise % jnp.uint32(max(V - 1, 1))
    use_jump = (noise % jnp.uint32(1000)) < jnp.uint32(int(1000 * (1 - cfg.copy_prob)))
    toks = jnp.where(use_jump, jump, walk).astype(jnp.int32) + 1  # avoid 0 (pad)
    return {"tokens": toks[:, :S], "labels": toks[:, 1:]}
