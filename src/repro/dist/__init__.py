"""repro.dist — the training-side counterpart of the netsim fabric model.

SeqBalance's motivating traffic mode is AI training: a handful of huge,
synchronized grad-sync collectives that ECMP cannot spread and that must
not reorder.  This package supplies that side of the reproduction:

  * ``collectives`` — PathPlan + the chunked, multipath, bidirectional ring
    all-reduce (the Shaper's N-sub-flow idea applied to grad sync);
  * ``sharding``    — FSDP+TP parameter/batch/cache partition rules for the
    production 16x16 (and 2x16x16 multi-pod) meshes;
  * ``elastic``     — phi-window path quarantine (LinkHealth), pod-failure
    remesh planning and the straggler watchdog;
  * ``netfeed``     — one netsim co-simulation cycle: PathPlan -> ring-trace
    workload -> fluid sim -> per-path congestion -> LinkHealth -> new plan;
  * ``cosim``       — the multi-epoch driver over a mutable fault schedule
    (killed/recovering spines, brown-outs): phi-expiry releases quarantined
    paths, per-epoch FCT/imbalance/plan-churn land in a CosimHistory, and
    link capacity rides through the sweep as a traced operand so every
    epoch reuses one compiled program (the Fig. 11 convergence story).

Importing the package installs the jax 0.4.x forward-compat shims
(``_compat``) so the modern sharding API the modules are written against
resolves on the pinned toolchain.
"""
from repro.dist import _compat  # noqa: F401  (installs jax API shims)
