"""Forward-compat shims: the dist layer (and the seed's system tests) are
written against the modern JAX sharding surface — ``jax.shard_map`` with
``axis_names=``/``check_vma=``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType`` and the two-argument ``AbstractMesh`` — while the
pinned toolchain ships jax 0.4.37, where the same machinery lives under
``jax.experimental.shard_map`` with the older ``auto=``/``check_rep=``
spelling.

Importing this module (``repro.dist`` does it on package import) installs
thin adapters into the ``jax`` namespace so the SAME source runs on both
generations.  Every patch is gated on ``hasattr``: on a modern JAX this
module is a no-op, and the adapters always delegate to the real
implementation — no behavior is re-implemented here.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


class _AxisType(enum.Enum):
    """Stand-in for jax.sharding.AxisType (mesh axes are implicitly Auto on
    0.4.x, so the annotation is accepted and dropped)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    # --- jax.make_mesh(..., axis_types=...) --------------------------------
    # signature probes only: building a probe mesh would initialize the
    # backend at import time, which launch/mesh.py promises not to do
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types  # implicit on 0.4.x
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    # --- two-argument AbstractMesh -----------------------------------------
    _AbstractMesh = jax.sharding.AbstractMesh
    if "shape_tuple" in inspect.signature(_AbstractMesh.__init__).parameters:

        @functools.wraps(_AbstractMesh, updated=())
        def AbstractMesh(axis_shapes, axis_names=None, *, axis_types=None):
            del axis_types
            if axis_names is None:  # old-style ((name, size), ...) call
                return _AbstractMesh(tuple(axis_shapes))
            return _AbstractMesh(tuple(zip(axis_names, axis_shapes)))

        jax.sharding.AbstractMesh = AbstractMesh

    # --- jax.shard_map ------------------------------------------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                      axis_names=None, check_vma=None, check_rep=None,
                      auto=None):
            if auto is None:
                if axis_names is None:
                    auto = frozenset()
                else:  # partial-manual: axes NOT named stay automatic
                    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            check = True if check_vma is None else check_vma
            if check_rep is not None:
                check = check_rep
            return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                              check_rep=check, auto=frozenset(auto))

        shard_map.is_legacy_shim = True  # callers can gate partial-manual use
        jax.shard_map = shard_map

    # --- jax.lax.axis_size --------------------------------------------------
    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a unit literal constant-folds to the (static) size of
            # the named axis inside shard_map/pmap tracing contexts.
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size


_install()
