"""SeqBalance multipath collective engine (paper §III applied to grad sync).

The paper's Shaper splits one elephant WQE into N sub-flows on distinct
QPs so the fabric can spread them over N paths with no reordering inside
any one of them.  ``seqbalance_all_reduce`` is the same idea one layer up:
the gradient bucket is cut into ``n_chunks`` chunks and each chunk runs its
OWN ring all-reduce (reduce-scatter + all-gather over ``lax.ppermute``)
whose ring *direction* is the chunk's path.  A congestion-quarantined path
(``PathPlan.inactive``, fed by ``dist.elastic.LinkHealth`` /
``dist.netfeed``) is simply skipped by the round-robin chunk->path map —
in-flight chunks never migrate, mirroring the paper's
"placed sub-flows never move" no-reordering rule.

Wire dtype is orthogonal: chunks can cross the fabric as float32,
bfloat16, or int8 (per-segment absmax scale), with accumulation always in
float32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist import _compat  # noqa: F401  (jax API shims)


@dataclasses.dataclass(frozen=True)
class PathPlan:
    """Static multipath plan for one collective.

    ``directions`` holds one ring direction (+1 / -1) per available path;
    ``inactive`` flags paths currently quarantined by congestion feedback.
    The plan is a *static* (hashable) argument: a new plan means a new
    compile, which is the point — path changes happen between steps, never
    inside one (no reordering).

    ``version`` is the plan's monotonic generation number (the planning
    epoch that produced it).  Plans travel from the planner to the QPs over
    the same imperfect control plane as the congestion reports, so a
    delivery can arrive late or twice; ``apply_plan`` refuses any candidate
    whose version does not EXCEED the plan currently applied — a reordered
    or duplicated delivery can never regress a QP to an older path table,
    which would silently move in-flight chunks (a reorder).
    """

    n_chunks: int = 4
    directions: tuple[int, ...] = (1, -1)
    inactive: tuple[bool, ...] | None = None
    wire_dtype: str = "float32"
    version: int = 0
    # token-based flowcell splitting BELOW the chunk (RDMACell's granularity,
    # the "other side" of the paper's no-reordering trade): each chunk's wire
    # traffic is cut into `flowcells` equal token cells, round-robined over
    # the active paths — so one chunk STRADDLES min(flowcells, n_active)
    # paths and pays the reordering cost the fluid model charges via
    # dataplane.reorder_gbn_factor.  flowcells=1 is bit-exactly the classic
    # per-chunk plan.  `reorder_budget` is the NIC's out-of-order absorption
    # in packets (0 = strict go-back-N); it rides along to the sim as the
    # traced `reorder` operand.
    flowcells: int = 1
    reorder_budget: float = 0.0

    def __post_init__(self):
        assert self.n_chunks >= 1
        assert all(d in (1, -1) for d in self.directions), self.directions
        if self.inactive is None:
            object.__setattr__(self, "inactive", (False,) * len(self.directions))
        assert len(self.inactive) == len(self.directions)
        assert self.wire_dtype in ("float32", "bfloat16", "int8"), self.wire_dtype
        assert self.flowcells >= 1, self.flowcells
        assert self.reorder_budget >= 0.0, self.reorder_budget

    @property
    def n_paths(self) -> int:
        return len(self.directions)

    def chunk_paths(self) -> tuple[int, ...]:
        """Round-robin chunk -> path assignment over the active paths.

        When every path is quarantined the table carries no routing signal
        (the paper: traffic must still flow) — fall back to the primary
        path rather than stalling the collective.
        """
        active = [p for p, dead in enumerate(self.inactive) if not dead]
        if not active:
            active = [0]
        return tuple(active[c % len(active)] for c in range(self.n_chunks))

    def flowcell_paths(self) -> tuple[tuple[int, ...], ...]:
        """Per-chunk flowcell -> path table: chunk c's cell j rides path
        ``active[(c + j) % n_active]`` — cell 0 is the chunk's classic
        round-robin path (so ``flowcells=1`` degenerates exactly to
        ``chunk_paths``), later cells walk the remaining active paths."""
        active = [p for p, dead in enumerate(self.inactive) if not dead]
        if not active:
            active = [0]
        return tuple(
            tuple(active[(c + j) % len(active)] for j in range(self.flowcells))
            for c in range(self.n_chunks)
        )


@dataclasses.dataclass(frozen=True)
class PinnedPlan:
    """A PathPlan whose chunk -> path table is EXPLICIT rather than derived
    round-robin — the output of in-epoch replanning (``replan_chunk_paths``).
    Duck-types ``PathPlan`` for everything that consumes plans
    (``workloads.collective_trace``, the ring engine): same ``n_chunks`` /
    ``directions`` / ``inactive`` / ``wire_dtype`` fields, but
    ``chunk_paths()`` returns the pinned table verbatim."""

    n_chunks: int
    directions: tuple[int, ...]
    inactive: tuple[bool, ...]
    paths: tuple[int, ...]  # chunk c -> path paths[c]
    wire_dtype: str = "float32"
    version: int = 0
    flowcells: int = 1
    reorder_budget: float = 0.0

    def __post_init__(self):
        assert len(self.paths) == self.n_chunks, (self.paths, self.n_chunks)
        assert len(self.inactive) == len(self.directions)
        assert all(0 <= p < len(self.directions) for p in self.paths)
        assert self.flowcells >= 1, self.flowcells
        assert self.reorder_budget >= 0.0, self.reorder_budget

    @property
    def n_paths(self) -> int:
        return len(self.directions)

    def chunk_paths(self) -> tuple[int, ...]:
        return tuple(self.paths)

    def flowcell_paths(self) -> tuple[tuple[int, ...], ...]:
        """Cell 0 keeps the PINNED path verbatim (replanning decided it);
        later cells walk the active paths from the pinned one."""
        active = [p for p, dead in enumerate(self.inactive) if not dead]
        if not active:
            active = [0]
        out = []
        for c, p0 in enumerate(self.paths):
            base = active.index(p0) if p0 in active else 0
            cells = (p0,) + tuple(
                active[(base + j) % len(active)] for j in range(1, self.flowcells)
            )
            out.append(cells)
        return tuple(out)


def apply_plan(current, candidate) -> tuple[object, bool]:
    """Versioned plan application: the no-reordering rule ACROSS plans.

    Returns ``(applied, took_candidate)``.  The candidate replaces the
    current plan only when its ``version`` strictly exceeds the applied
    one; a stale (reordered) or repeated (duplicated) delivery is refused
    and the current table stays in force.  Applying an OLDER table would
    retroactively move chunks whose packets are already committed to the
    newer table's paths — the cross-version spelling of "placed sub-flows
    never move".  Refusal is idempotence, not an error: the caller counts
    refusals (``dist.cosim`` records them) but keeps running."""
    if candidate.version <= current.version:
        return current, False
    return candidate, True


def replan_chunk_paths(paths: tuple[int, ...], directions: tuple[int, ...],
                       inactive: tuple[bool, ...],
                       in_flight: tuple[int, ...] = ()) -> tuple[int, ...]:
    """Mid-collective replan: move chunks off newly-quarantined paths onto
    surviving ones WITHOUT ever reordering a chunk.

    The no-reordering rule, per chunk:

      * a chunk in ``in_flight`` keeps its path unconditionally — its
        packets are already interleaved on the wire, and a migration would
        race them (exactly the per-sub-flow rule of the paper's Shaper);
      * a migrating chunk may only move to a path with the SAME ring
        direction — flipping direction renumbers every segment the chunk
        has already reduced, which is a reorder of its own stream;
      * if no same-direction path survives, the chunk STAYS on its
        quarantined path (graceful degradation: a slow path delivers late
        but in order; a direction flip delivers wrong).

    Surviving chunks on healthy paths are untouched.  Migrants spread
    round-robin over the same-direction survivors."""
    assert len(directions) == len(inactive)
    in_flight_set = set(in_flight)
    survivors: dict[int, list[int]] = {}
    for p, d in enumerate(directions):
        if not inactive[p]:
            survivors.setdefault(d, []).append(p)
    out: list[int] = []
    rr: dict[int, int] = {}
    for c, p in enumerate(paths):
        if c in in_flight_set or not inactive[p]:
            out.append(p)
            continue
        same_dir = survivors.get(directions[p], [])
        if not same_dir:
            out.append(p)  # degraded: in-order on a slow path beats a flip
            continue
        k = rr.get(directions[p], 0)
        out.append(same_dir[k % len(same_dir)])
        rr[directions[p]] = k + 1
    return tuple(out)


# ------------------------------------------------------------- wire dtypes
def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmax int8 quantization: returns (q int8, scale f32 scalar) with
    x ~= q * scale and |x - q*scale| <= scale/2 (round-to-nearest)."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _encode(x, wire: str):
    if wire == "bfloat16":
        # ship the raw bf16 bits: bitcasting to uint16 pins the 2-byte wire
        # format in the lowered HLO (a plain astype round-trip gets hoisted
        # across the ppermute by XLA's simplifier, silently widening the
        # wire back to 4 bytes)
        return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)
    if wire == "int8":
        return quantize_int8(x)
    return x


def _decode(y, wire: str):
    if wire == "bfloat16":
        return jax.lax.bitcast_convert_type(y, jnp.bfloat16).astype(jnp.float32)
    if wire == "int8":
        return dequantize_int8(*y)
    return y


def _permute(payload, axis_name, perm):
    return jax.tree.map(lambda a: jax.lax.ppermute(a, axis_name, perm), payload)


# ------------------------------------------------------------ ring engine
def _ring_all_reduce(v: jax.Array, axis_name: str, d: int, n: int, wire: str):
    """One chunk's ring all-reduce.  ``v`` is f32[n, seg] (one segment per
    ring member); direction ``d`` is the chunk's path.  2*(n-1) ppermute
    rounds: reduce-scatter then all-gather, exactly the bandwidth-optimal
    schedule the fabric sees as one long-lived flow per neighbor pair."""
    if n == 1:
        return v
    i = jax.lax.axis_index(axis_name)
    perm = [(src, (src + d) % n) for src in range(n)]

    def seg(arr, idx):
        return jax.lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    def put(arr, val, idx):
        return jax.lax.dynamic_update_index_in_dim(arr, val, idx % n, axis=0)

    # reduce-scatter: after step s, device i holds the partial sum of s+1
    # contributions in segment (i - (s+1)*d); after n-1 steps its segment
    # (i + d) is fully reduced.
    for s in range(n - 1):
        send = seg(v, i - s * d)
        recv = _decode(_permute(_encode(send, wire), axis_name, perm), wire)
        ridx = i - (s + 1) * d
        v = put(v, seg(v, ridx) + recv, ridx)

    # all-gather: circulate the reduced segments the opposite way around
    # the same ring (send what you last received).
    for s in range(n - 1):
        send = seg(v, i + d - s * d)
        recv = _decode(_permute(_encode(send, wire), axis_name, perm), wire)
        v = put(v, recv, i - s * d)
    return v


def seqbalance_all_reduce(x: jax.Array, axis_name: str, plan: PathPlan | None = None):
    """Multipath chunked ring all-reduce of ``x`` over ``axis_name``.

    Must be called inside ``shard_map`` (manual over ``axis_name``).
    Returns the full sum with ``x``'s shape and dtype; equals
    ``lax.psum(x, axis_name)`` up to wire-dtype rounding.
    """
    plan = PathPlan() if plan is None else plan
    n = jax.lax.axis_size(axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    m = flat.size
    c = plan.n_chunks
    seg = -(-max(m, 1) // (c * n))
    flat = jnp.pad(flat, (0, c * n * seg - m))
    chunks = flat.reshape(c, n, seg)
    paths = plan.chunk_paths()
    reduced = [
        _ring_all_reduce(chunks[k], axis_name, int(plan.directions[paths[k]]),
                         int(n), plan.wire_dtype)
        for k in range(c)
    ]
    out = jnp.stack(reduced).reshape(-1)[:m].reshape(shape)
    return out.astype(dtype)


# ----------------------------------------------------------- conveniences
def baseline_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Stock XLA all-reduce — the single-path elephant flow the paper's
    motivation describes (one fat all-reduce per gradient)."""
    return jax.lax.psum(x, axis_name)


def tree_all_reduce_mean(tree, axis_name: str, plan: PathPlan | None = None):
    """Grad sync: SeqBalance all-reduce each leaf, then divide by the axis
    size (data-parallel mean)."""
    n = jax.lax.axis_size(axis_name)

    def one(g):
        s = seqbalance_all_reduce(g, axis_name, plan)
        return (s.astype(jnp.float32) / n).astype(g.dtype)

    return jax.tree.map(one, tree)
