"""Multi-epoch co-simulation driver: the paper's Fig. 11 convergence story.

``dist.netfeed.co_simulate`` closes ONE plan -> trace -> fluid-sim ->
health -> plan cycle.  This module iterates it over a mutable FAULT
SCHEDULE — spines killed at epoch k, recovering at epoch k + m, capacity
brown-outs — and records per-epoch FCT / imbalance / plan-churn into a
``CosimHistory`` the benches plot as convergence curves and CDFs:

  epoch t:  capacity_t = capacity_at(topo, faults, t)      (fault state)
            trace_t    = collective_trace(plan_t, ...)     (ring schedule;
                         ECMP-steered so plan_t's chunk->path map BINDS)
            sim        = sweep.run_one(..., capacity=capacity_t)
            reports    = report_congestion(health, ..., step=t)
            plan_{t+1} = health.plan(t + 1)                (phi-expiry:
                         a path re-enters exactly phi_steps after its
                         last report — recovered spines are released)

Two contracts make the loop cheap and honest:

  * capacity is a TRACED sweep operand (netsim/sweep.py), so every epoch
    after the first reuses the one compiled program no matter how the
    fault schedule mutates link capacities — ``EpochRecord.new_builds``
    proves it per epoch from ``sweep.cache_stats()``;
  * the ring cadence and the per-flow slot window are fixed from the
    HEALTHY topology at epoch 0 (the collective's schedule does not know
    about faults, and one slot per flow makes spill — and therefore
    shape-changing retries — impossible), so trace shapes never drift.

``run_cosim_grid`` fans a (scheme x ring size x fault schedule x seed)
grid through ``netsim.sweep.run_jobs`` — including paper-scale
``three_tier`` (320 hosts) — one callable job per grid point.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time

import numpy as np

from repro.dist import netfeed
from repro.dist.elastic import LinkHealth


# ---------------------------------------------------------- fault schedule
@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Capacity of ``links`` is multiplied by ``scale`` for epochs in
    [start_epoch, end_epoch) — end_epoch None means the fault never
    recovers.  scale 0.0 is a hard failure; 0 < scale < 1 a brown-out."""

    start_epoch: int
    links: tuple[int, ...]
    scale: float = 0.0
    end_epoch: int | None = None

    def __post_init__(self):
        # an empty-links or end<=start event is always a typo'd schedule:
        # it silently applies to nothing / never, and the bench reads the
        # run as a (vacuously) healthy fault epoch
        assert len(self.links) > 0, "FaultEvent with no links is a no-op"
        assert self.start_epoch >= 0, self.start_epoch
        assert self.scale >= 0.0, self.scale
        if self.end_epoch is not None:
            assert self.end_epoch > self.start_epoch, \
                (self.start_epoch, self.end_epoch)

    def active(self, epoch: int) -> bool:
        return self.start_epoch <= epoch and (
            self.end_epoch is None or epoch < self.end_epoch)


def kill_spine(topo, spine: int, *, epoch: int,
               recover_epoch: int | None = None) -> FaultEvent:
    """Hard-fail one fabric switch (leaf_spine: a spine; three_tier: an
    aggregation switch — see ``topology.spine_links``)."""
    from repro.netsim.topology import spine_links

    return FaultEvent(epoch, spine_links(topo, spine), 0.0, recover_epoch)


def brownout_spine(topo, spine: int, scale: float, *, epoch: int,
                   recover_epoch: int | None = None) -> FaultEvent:
    """Degrade one fabric switch's links to ``scale`` x capacity."""
    from repro.netsim.topology import spine_links

    assert 0.0 < scale < 1.0, scale
    return FaultEvent(epoch, spine_links(topo, spine), scale, recover_epoch)


def capacity_at(topo, faults, epoch: int) -> np.ndarray:
    """The epoch's link-capacity vector (f32[n_links + 1], sentinel slot
    preserved): base topology capacity with every active fault applied."""
    cap = np.asarray(topo.capacity, np.float32).copy()
    for ev in faults:
        if ev.active(epoch):
            cap[list(ev.links)] *= np.float32(ev.scale)
    return cap


def ring_hosts(topo, n: int) -> list[int]:
    """``n`` ring members striped across leaves (host i lives on leaf
    i % n_leaf) — the pod-gateway pattern: consecutive ring neighbors are
    always on different racks while n <= n_leaf, so every ring segment
    crosses the fabric."""
    L, hpl = topo.n_leaf, topo.hosts_per_leaf
    assert 2 <= n <= topo.n_hosts, (n, topo.n_hosts)
    return [(i % L) * hpl + (i // L) for i in range(n)]


# ------------------------------------------------------------- epoch record
@dataclasses.dataclass
class EpochRecord:
    """One planning epoch's observables.  FCTs are CENSORED at the horizon
    (metrics.fct_samples): a killed spine starves flows outright and a
    survivors-only p99 would read the disaster epoch as healthy."""

    epoch: int
    fct_p50_s: float
    fct_p99_s: float
    fct_mean_s: float
    completion: float
    imbalance_mean: float
    plan_churn: int  # inactive-flag flips between this plan and the next
    quarantined: tuple[int, ...]  # paths inactive in THIS epoch's plan
    reported_slow: tuple[int, ...]  # paths report_congestion flagged
    spill_steps: int
    new_builds: int  # sweep executables built this epoch (0 after epoch 0)
    fct: np.ndarray  # censored per-flow samples (CDFs)
    imbalance: np.ndarray  # per-(ToR, window) imbalance samples
    # --- chaos-campaign observables (defaults keep legacy callers intact)
    replan_round: int = -1  # in-epoch replanning cut round (-1 = none)
    straggler_scale: float = 1.0  # cadence stretch the ring actually paid
    straggler_quarantined: tuple[int, ...] = ()  # ranks the policy benched
    # --- degraded-telemetry observables (-1 / False = no channel in play)
    safe_mode: bool = False  # epoch RAN on the blind ECMP fallback
    plan_version: int = -1  # version of the plan in force this epoch
    reports_sent: int = -1  # telemetry payloads emitted this epoch
    reports_delivered: int = -1  # payloads the channel delivered this epoch
    reports_admitted: int = -1  # deliveries admitted by the staleness gate
    reports_stale: int = -1  # deliveries older than the staleness bound
    reports_duplicate: int = -1  # duplicated deliveries (idempotently dropped)
    # --- adaptive-dt observables (0 = fixed dt or nothing fast-forwarded)
    ff_steps: int = 0  # dt steps the quiescence fast-forward covered
    # --- in-sim recorder drain (None = no RecordSpec passed to run_cosim)
    insim: dict | None = None  # obs.epoch_summary of the epoch's ring buffer


@dataclasses.dataclass
class CosimHistory:
    """The driver's full output: per-epoch records, the plan sequence, and
    the LinkHealth whose phi windows produced it."""

    scheme: str
    phi_steps: int
    duration_s: float
    records: list[EpochRecord]
    plans: list  # PathPlan used in epoch t (len == epochs)
    final_plan: object  # plan for epoch `epochs` (what a deployment ships)
    health: LinkHealth
    plan_refused: int = 0  # newer-plan applications refused (gate: zero)

    @property
    def epochs(self) -> int:
        return len(self.records)

    def baseline_p99(self, fault_epoch: int) -> float:
        """Pre-failure reference: median censored p99 over the epochs
        before the first fault (epoch 0 if the fault hits immediately)."""
        pre = [r.fct_p99_s for r in self.records[:max(fault_epoch, 1)]]
        return float(np.median(pre))

    def convergence_epoch(self, fault_epoch: int,
                          tol: float = 0.10) -> int | None:
        """First epoch >= ``fault_epoch`` whose censored p99 FCT is back
        within ``tol`` of the pre-failure baseline with every flow
        completing — the paper's "FCT recovers within a few epochs"
        claim, as a number.  None = never converged."""
        base = self.baseline_p99(fault_epoch)
        for r in self.records[fault_epoch:]:
            if r.completion >= 1.0 and r.fct_p99_s <= (1.0 + tol) * base:
                return r.epoch
        return None

    def fct_cdf(self, epochs: list[int] | None = None, points: int = 50):
        from repro.netsim import metrics

        rs = self.records if epochs is None else \
            [r for r in self.records if r.epoch in epochs]
        return metrics.cdf(np.concatenate([r.fct for r in rs]), points)

    def imbalance_cdf(self, epochs: list[int] | None = None,
                      points: int = 50):
        from repro.netsim import metrics

        rs = self.records if epochs is None else \
            [r for r in self.records if r.epoch in epochs]
        samples = np.concatenate([r.imbalance for r in rs]) if any(
            r.imbalance.size for r in rs) else np.zeros(1)
        return metrics.cdf(samples, points)

    def as_record(self) -> dict:
        """JSON-able per-epoch curves for BENCH_netsim.json."""
        rs = self.records
        return dict(
            scheme=self.scheme,
            phi_steps=self.phi_steps,
            epochs=self.epochs,
            duration_ms=round(self.duration_s * 1e3, 3),
            p50_us=[round(r.fct_p50_s * 1e6, 2) for r in rs],
            p99_us=[round(r.fct_p99_s * 1e6, 2) for r in rs],
            completion=[round(r.completion, 4) for r in rs],
            imbalance_mean=[round(r.imbalance_mean, 4) for r in rs],
            plan_churn=[r.plan_churn for r in rs],
            n_quarantined=[len(r.quarantined) for r in rs],
            spill_steps=[r.spill_steps for r in rs],
            new_builds=[r.new_builds for r in rs],
            replan_round=[r.replan_round for r in rs],
            straggler_scale=[round(r.straggler_scale, 3) for r in rs],
            n_straggler_quarantined=[len(r.straggler_quarantined) for r in rs],
            safe_mode=[bool(r.safe_mode) for r in rs],
            plan_version=[r.plan_version for r in rs],
            reports_sent=[r.reports_sent for r in rs],
            reports_delivered=[r.reports_delivered for r in rs],
            reports_admitted=[r.reports_admitted for r in rs],
            reports_stale=[r.reports_stale for r in rs],
            reports_duplicate=[r.reports_duplicate for r in rs],
        )

    def summary_lines(self) -> list[str]:
        return [
            f"epoch {r.epoch:2d} p99 {r.fct_p99_s * 1e6:8.1f}us "
            f"done {r.completion:5.3f} quar {len(r.quarantined):3d} "
            f"churn {r.plan_churn:3d} builds {r.new_builds}"
            for r in self.records
        ]


# ----------------------------------------------------------- epoch journal
JOURNAL_SCHEMA_VERSION = 2


class JournalSchemaError(RuntimeError):
    """A cosim journal written by an incompatible driver version.  Raised
    (never silently restarted over) because a schema mismatch means the
    journal may hold epochs this driver would MISPARSE — the user must
    delete or migrate the file explicitly.  A *spec* mismatch (same schema,
    different campaign) still restarts silently: that is a different run,
    not a different format."""


def _rec_to_json(r: EpochRecord) -> dict:
    d = dataclasses.asdict(r)
    d["fct"] = np.asarray(r.fct, np.float32).tolist()
    d["imbalance"] = np.asarray(r.imbalance, np.float32).tolist()
    for k in ("quarantined", "reported_slow", "straggler_quarantined"):
        d[k] = list(d[k])
    return d


def _rec_from_json(d: dict) -> EpochRecord:
    d = dict(d)
    d["fct"] = np.asarray(d["fct"], np.float32)
    d["imbalance"] = np.asarray(d["imbalance"], np.float32)
    for k in ("quarantined", "reported_slow", "straggler_quarantined"):
        d[k] = tuple(d.get(k, ()))
    return EpochRecord(**d)


def _load_journal(journal: str, spec_key: dict):
    """Parse a campaign journal.  Returns (records, epoch_states) for a
    journal whose header matches ``spec_key``; None for a missing,
    spec-mismatched (different campaign — restart, don't splice), or
    corrupt file; raises ``JournalSchemaError`` for a cosim journal whose
    ``schema_version`` this driver does not speak (resuming over it could
    misparse epochs).  ``epoch_states`` are the per-epoch (plan_inactive,
    health, straggler, telemetry, watchdog) snapshots; the LAST one is the
    exact driver state to resume from."""
    import json
    import os

    if not os.path.exists(journal):
        return None
    try:
        with open(journal) as fh:
            raw = [ln for ln in fh if ln.strip()]
    except OSError:
        return None
    if not raw:
        return None
    try:
        head = json.loads(raw[0])
    except ValueError:
        return None
    if not isinstance(head, dict) or head.get("journal") != "cosim":
        return None
    schema = head.get("schema_version", head.get("version"))
    if schema != JOURNAL_SCHEMA_VERSION:
        raise JournalSchemaError(
            f"cosim journal {journal!r} has schema_version={schema!r} but "
            f"this driver writes schema_version={JOURNAL_SCHEMA_VERSION}; "
            "refusing to resume over an incompatible format — delete the "
            "journal (restarts the campaign) or replay it with the driver "
            "version that wrote it")
    if head.get("spec") != spec_key:
        return None
    records, states = [], []
    for ln in raw[1:]:
        # a torn tail line IS the interruption artifact: keep the prefix
        try:
            d = json.loads(ln)
            records.append(_rec_from_json(d["record"]))
            states.append(d)
        except (ValueError, KeyError, TypeError):
            break
    return records, states


# ------------------------------------------------------------------ driver
def run_cosim(
    topo,
    hosts,
    size_bytes: float,
    *,
    scheme: str = "ecmp",
    epochs: int = 8,
    faults: tuple = (),
    campaign=None,
    phi_steps: int = 2,
    cooldown_steps: int = 0,
    n_chunks: int = 8,
    wire_dtype: str = "float32",
    dt: float = 10e-6,
    duration_s: float | None = None,
    overload: float = 1.5,
    steer: bool = True,
    replan: bool = True,
    detect_delay_s: float | None = None,
    health: LinkHealth | None = None,
    straggler_policy=None,
    straggler_deadline_frac: float = 1.5,
    seed: int = 0,
    window_slots: int | None = None,
    imbalance_sample_every: int = 10,
    journal: str | None = None,
    telemetry=None,
    staleness_bound: int | None = None,
    blackout_epochs: int = 3,
    record=None,
    flight=None,
    flowcells: int = 1,
    reorder_budget: float | None = None,
    **cfg_kw,
) -> CosimHistory:
    """Run ``epochs`` plan -> sim -> health cycles over a fault schedule.

    ``hosts`` are the ring members (``ring_hosts`` for the gateway
    pattern); ``size_bytes`` the per-member all-reduce payload.  The ring
    cadence is fixed from the healthy topology: one round every
    max(segment serialization on the fabric, n_chunks segments on the host
    NIC) — so every epoch's trace has identical shapes and the traced
    capacity operand is the ONLY thing that changes with the fault state.
    ``window_slots`` defaults to one slot per flow, which makes spill
    impossible (a fault epoch can hold every flow in flight at once) and
    therefore keeps the compiled program's shapes pinned.

    Chaos-campaign extensions (all no-ops when unused):

      * ``campaign`` (``netsim.faults.FaultCampaign``) compiles per epoch
        into a WALL-CLOCK capacity schedule f32[K, n_links + 1] + a loss
        vector, threaded through the sweep as traced operands — flaps and
        PFC pauses land mid-horizon, lossy links drive go-back-N goodput
        amplification inside the dataplane, and every epoch still reuses
        the one compiled program (K is campaign-constant).  Epoch-level
        ``faults`` compose on top.
      * in-epoch replanning (``replan=True``, needs ``steer``): a campaign
        flap with an intra-epoch onset is DETECTED ``detect_delay_s``
        (default: two ring rounds) after it lands; rounds before the cut
        run the original plan, rounds after run a
        ``collectives.replan_chunk_paths`` pinned plan — in-flight rounds
        keep their QP flow ids, surviving steered QPs keep theirs, only
        QPs whose fabric path died re-steer (the no-reordering rule).
        When every active path died, chunks/QPs fall back to the primary
        path rather than stalling.
      * stragglers: campaign ``Straggler`` events stretch their rank's
        step duration; ``straggler_policy`` (auto-created when the
        campaign has stragglers) observes every rank per epoch, and ranks
        it quarantines stop gating the bulk-synchronous cadence — the
        ring's effective round gap is the slowest NON-quarantined rank.
      * ``cooldown_steps`` enables LinkHealth's flap hysteresis (re-report
        within the cooldown doubles the path's phi window).
      * ``journal`` (a file path) appends one JSON line per completed
        epoch; re-running with the same spec resumes after the last
        journaled epoch instead of restarting the campaign (exact driver
        state — records, health phi windows, straggler misses, telemetry
        queue, watchdog — restores from the journal tail; a spec mismatch
        restarts from scratch, a ``schema_version`` mismatch raises
        ``JournalSchemaError``).

    Degraded-telemetry extensions (``telemetry`` is a
    ``netsim.faults.TelemetryChannel``; ``telemetry=None`` is bit-identical
    to the legacy perfect-feedback driver):

      * every slow path ``netfeed.observe_congestion`` sees is SENT through
        the channel as an epoch-stamped ``("slow", path)`` report — plus
        one ``("hb", leaf)`` liveness heartbeat per leaf — and only what
        the channel delivers reaches the planner, admitted through
        ``LinkHealth.admit_report`` against ``staleness_bound`` (stale
        reports discarded, duplicated deliveries idempotent);
      * plans apply through ``collectives.apply_plan``: versions are
        strictly monotone across epochs, a replayed older plan is refused
        (asserted every epoch), and unexpected refusals of genuinely newer
        plans are counted (the bench gates on zero);
      * a ``dist.elastic.TelemetryWatchdog`` watches admissible deliveries:
        ``blackout_epochs`` silent epochs flip the driver into SAFE MODE —
        the epoch runs an all-paths-active plan with steering OFF (plain
        ECMP five-tuple hashing; same trace shapes, so the compiled
        program is reused) instead of steering on stale quarantines — and
        one admissible delivery after the channel heals flips it back.

    Observability extensions (DESIGN.md §16; both default off and change
    nothing when unused):

      * ``record`` (an ``obs.RecordSpec``) threads the traced in-sim ring
        buffer through every epoch's sim: the recorder costs exactly ONE
        extra executable per shape bucket (built at epoch 0, zero rebuilds
        after), the drained per-chunk summaries land on
        ``EpochRecord.insim`` via ``obs.epoch_summary``, and the spec
        joins the journal's ``spec_key`` so a resumed campaign can't mix
        recorded and unrecorded epochs.
      * ``flight`` (a path, or an open ``obs.FlightLog``) appends one
        schema-v2 JSONL event per epoch — wall-clock span, FCT stats,
        plan/quarantine/watchdog/telemetry state, sweep build + resilience
        counters, hot uplinks, fault activations, and the in-sim drain —
        plus a leading ``campaign`` event and a trailing ``run_end`` with
        the convergence verdict.  ``obs.trace_export`` renders the file as
        a perfetto timeline; ``obs.features.epoch_matrix`` lifts it into
        [epoch, uplink, feature] arrays.  A path is opened/closed by this
        call; an instance is shared (caller closes).

    Flowcell extensions (DESIGN.md §17; defaults are bit-identical to the
    pre-flowcell driver):

      * ``flowcells`` > 1 splits every chunk-QP into that many flowcells
        sprayed round-robin over the plan's active paths (each cell keeps
        its own five-tuple, so the split reuses the steering machinery —
        the trace just carries more, smaller flows plus a ``spray``
        column).
      * ``reorder_budget`` (packets, or None) turns on the explicit
        reordering-cost model: sprayed flows pay the go-back-N
        amplification ``dataplane.reorder_gbn_factor`` charges for
        inter-path skew beyond the budget.  It rides the sweep as a traced
        scalar operand, so every epoch and every budget reuses ONE
        compiled program; ``None`` traces the identical pre-flowcell
        program (the "reordering is free" bench arm).
    """
    from repro.dist import collectives
    from repro.netsim import compact, metrics, sweep, workloads
    from repro.netsim.engine import SimConfig

    hosts = list(hosts)
    n = len(hosts)
    if health is None:
        health = LinkHealth(n_paths=topo.n_paths, phi_steps=phi_steps,
                            cooldown_steps=cooldown_steps,
                            max_staleness_epochs=staleness_bound)
    else:
        phi_steps = health.phi_steps

    watchdog = None
    if telemetry is not None:
        from repro.dist.elastic import TelemetryWatchdog

        watchdog = TelemetryWatchdog(blackout_epochs=blackout_epochs)

    cap0 = np.asarray(topo.capacity)
    fabric_bw = float(np.median(cap0[np.asarray(topo.uplink_ids)]))
    host_bw = float(cap0[topo.n_links - 2 * topo.n_hosts])
    seg_bytes = size_bytes / (n * n_chunks)
    # a member serializes all n_chunks segments of a round through one NIC
    gap = max(seg_bytes * 8.0 / fabric_bw, n_chunks * seg_bytes * 8.0 / host_bw)
    rounds = 2 * (n - 1)
    if duration_s is None:
        duration_s = rounds * gap * 2.5 + 50 * dt
    n_steps = max(int(math.ceil(duration_s / dt)), 1)
    duration_s = n_steps * dt
    cfg = SimConfig(scheme=scheme, duration_s=duration_s, dt=dt, **cfg_kw)

    policy = straggler_policy
    if policy is None and campaign is not None and campaign.has_stragglers():
        from repro.dist.elastic import StragglerPolicy

        policy = StragglerPolicy(deadline_s=gap * straggler_deadline_frac,
                                 max_misses=2)

    # ---------------- journal: resume a previously interrupted campaign
    start_epoch = 0
    records: list[EpochRecord] = []
    plans: list = []
    spec_key = dict(
        scheme=scheme, epochs=epochs, hosts=[int(h) for h in hosts],
        size_bytes=float(size_bytes), phi_steps=phi_steps,
        cooldown_steps=cooldown_steps, n_chunks=n_chunks, seed=seed,
        steer=bool(steer), replan=bool(replan),
        topo=dict(kind=topo.kind, n_links=topo.n_links, n_paths=topo.n_paths),
        telemetry=None if telemetry is None else telemetry.config(),
        staleness_bound=staleness_bound,
        blackout_epochs=blackout_epochs if telemetry is not None else None,
    )
    if record is not None:
        # JSON-normalized (lists, not tuples) so a resumed journal's loaded
        # spec compares equal; absent entirely when unused so legacy
        # journals written before the recorder existed still match
        spec_key["record"] = dict(
            ring_chunks=int(record.ring_chunks),
            quantiles=[float(q) for q in record.quantiles])
    if flowcells != 1 or reorder_budget is not None:
        # same legacy-journal convention as ``record``: the key exists only
        # when the feature is used, so pre-flowcell journals still match
        spec_key["flowcell"] = dict(
            flowcells=int(flowcells),
            reorder_budget=None if reorder_budget is None
            else float(reorder_budget))

    def _fc(p):
        # stamp the split factor onto every plan the driver runs; plans are
        # frozen dataclasses, so this is a copy — health/journal state keeps
        # the unstamped originals
        return dataclasses.replace(p, flowcells=int(flowcells)) \
            if flowcells != 1 else p
    journal_fh = None
    if journal is not None:
        import json

        loaded = _load_journal(journal, spec_key)
        if loaded is not None:
            records, states = loaded
            start_epoch = len(records)
            if states:
                health.restore(states[-1]["health"])
                if policy is not None and states[-1].get("straggler"):
                    policy.restore(states[-1]["straggler"])
                if telemetry is not None and states[-1].get("telemetry"):
                    telemetry.restore(states[-1]["telemetry"])
                if watchdog is not None and states[-1].get("watchdog"):
                    watchdog.restore(states[-1]["watchdog"])
            for st in states:
                plans.append(collectives.PathPlan(
                    n_chunks=n_chunks, directions=tuple(health.directions),
                    inactive=tuple(bool(b) for b in st["plan_inactive"]),
                    wire_dtype=wire_dtype,
                    version=int(st["record"].get("plan_version", 0))))
        # (re)write header + the valid prefix: drops any torn tail line
        # left by the interruption so the resumed journal stays parseable
        journal_fh = open(journal, "w")
        journal_fh.write(json.dumps(dict(
            journal="cosim", schema_version=JOURNAL_SCHEMA_VERSION,
            spec=spec_key)) + "\n")
        for st in (loaded[1] if loaded is not None else ()):
            journal_fh.write(json.dumps(st) + "\n")
        journal_fh.flush()

    # ---------------- flight log: control-plane event stream (obs plane)
    fl = None
    fl_owned = False
    if flight is not None:
        from repro.obs import FlightLog

        if isinstance(flight, FlightLog):
            fl = flight
        else:
            fl = FlightLog(flight, meta=dict(spec=spec_key))
            fl_owned = True
        fl.event(
            "campaign", scheme=scheme, epochs=epochs, start_epoch=start_epoch,
            n_hosts=n, size_bytes=float(size_bytes), n_steps=n_steps,
            duration_s=duration_s, dt=dt, n_chunks=n_chunks,
            n_faults=len(faults) + (len(campaign.events)
                                    if campaign is not None else 0),
            telemetry=spec_key["telemetry"],
            record=spec_key.get("record"))

    plan = health.plan(start_epoch, n_chunks=n_chunks, wire_dtype=wire_dtype)
    plan_refused = 0
    W = window_slots
    try:
        for epoch in range(start_epoch, epochs):
            t_ep = time.time()  # epoch wall-clock span for the flight log
            # ------------------------------------- safe-mode plan selection
            # entering state of the watchdog decides THIS epoch's conduct:
            # blind planners don't steer — run everything-active, unsteered
            in_safe = watchdog is not None and watchdog.safe_mode
            if in_safe:
                run_plan = collectives.PathPlan(
                    n_chunks=n_chunks, directions=tuple(health.directions),
                    inactive=None, wire_dtype=wire_dtype,
                    version=plan.version)
            else:
                run_plan = plan

            # -------------------------------------------- fault state
            if campaign is not None:
                cap = campaign.capacity_schedule(topo, epoch)  # [K, nl+1]
                for ev in faults:  # epoch-level faults compose on top
                    if ev.active(epoch):
                        cap[:, list(ev.links)] *= np.float32(ev.scale)
                # adaptive dt: align the segment stride to the scan-chunk
                # grid so no chunk straddles a capacity edge (the quiescence
                # predicate would refuse to fast-forward it); fixed dt keeps
                # the PR 6 uniform stride bit-identical
                K_chunk, _, _ = compact.plan_chunks(cfg, n_steps)
                cap_seg = campaign.seg_steps(
                    n_steps, align=K_chunk if cfg.adaptive else 1)
                loss = campaign.loss_at(topo, epoch)
                # congestion reporting sees the epoch's WORST capacity: a
                # link that flapped at all this epoch reads as degraded
                cap_report = cap.min(axis=0)
                slowdowns = campaign.straggler_slowdowns(epoch)
            else:
                cap = capacity_at(topo, faults, epoch)
                cap_seg, loss, cap_report = 0, None, cap
                slowdowns = {}

            # -------------------------------------------- stragglers
            strag_quar: tuple[int, ...] = ()
            if policy is not None:
                for i in range(n):
                    policy.observe(i, gap * slowdowns.get(i, 1.0))
                strag_quar = policy.quarantined()
            eff = max([slowdowns.get(i, 1.0) for i in range(n)
                       if i not in strag_quar] or [1.0])
            gap_e = gap * eff  # slowest non-quarantined rank gates the ring

            # ------------------------------- trace (+ in-epoch replanning)
            steer_p = topo.n_paths if steer and not in_safe else None
            onset = campaign.midepoch_onset(topo, epoch) if campaign else None
            replan_round = -1
            if onset is not None and replan and steer and not in_safe \
                    and onset.paths:
                t_detect = onset.frac * duration_s + (
                    detect_delay_s if detect_delay_s is not None else 2 * gap_e)
                r_cut = int(math.ceil(t_detect / gap_e))
                if 0 < r_cut < rounds:
                    replan_round = r_cut
            if replan_round > 0:
                # the fault is observed mid-collective: report it NOW so
                # both this epoch's tail and the next plan route around it
                for p in onset.paths:
                    health.report_slow(p, epoch)
                inact2 = tuple(d or (p in set(onset.paths))
                               for p, d in enumerate(plan.inactive))
                pinned = collectives.PinnedPlan(
                    n_chunks=n_chunks, directions=tuple(plan.directions),
                    inactive=inact2,
                    paths=collectives.replan_chunk_paths(
                        plan.chunk_paths(), tuple(plan.directions), inact2),
                    wire_dtype=wire_dtype)
                # steering targets: surviving QPs keep their fid (their
                # stream stays on its path — no reorder); only QPs whose
                # fabric path died re-steer, round-robin over survivors,
                # falling back to the primary path when none survive
                active0 = [p for p, d in enumerate(plan.inactive)
                           if not d] or [0]
                tgt = np.array(
                    [[active0[(i * n_chunks + c) % len(active0)]
                      for i in range(n)] for c in range(n_chunks)], np.int32)
                dead = set(onset.paths)
                surv = [p for p in active0 if p not in dead] or [0]
                tgt_b, k = tgt.copy(), 0
                for c in range(n_chunks):
                    for i in range(n):
                        if int(tgt[c, i]) in dead:
                            tgt_b[c, i] = surv[k % len(surv)]
                            k += 1
                tr_a = workloads.collective_trace(
                    _fc(plan), hosts, size_bytes, link_bw=fabric_bw,
                    round_gap_s=gap_e, rounds=replan_round, seed=seed,
                    steer_paths=steer_p, steer_targets=tgt)
                tr_b = workloads.collective_trace(
                    _fc(pinned), hosts, size_bytes, link_bw=fabric_bw,
                    round_gap_s=gap_e, rounds=rounds - replan_round,
                    start_s=replan_round * gap_e, seed=seed,
                    steer_paths=steer_p, steer_targets=tgt_b)
                trace = workloads.merge_traces(tr_a, tr_b)
            else:
                trace = workloads.collective_trace(
                    _fc(run_plan), hosts, size_bytes, link_bw=fabric_bw,
                    round_gap_s=gap_e, seed=seed, steer_paths=steer_p)
            if W is None:
                W = int(trace.valid.sum())  # spill-proof: one slot per flow

            # -------------------------------------------------- simulate
            b0 = sweep.cache_stats()["builds"]
            result, outs = sweep.run_one(topo, cfg, trace, capacity=cap,
                                         loss=loss, cap_seg_steps=cap_seg,
                                         window_slots=W, record=record,
                                         reorder=reorder_budget)
            new_builds = sweep.cache_stats()["builds"] - b0
            insim = None
            if record is not None and getattr(result, "ring", None) is not None:
                from repro import obs

                insim = obs.epoch_summary(record, obs.drain(record, result.ring))

            # ------------------------------------ congestion feedback path
            n_sent = n_delivered = n_admitted = n_stale = n_dup = -1
            if telemetry is None:
                # perfect channel: the legacy direct path, bit-identical
                slow = netfeed.report_congestion(
                    health, topo, outs, step=epoch, overload=overload,
                    capacity=cap_report, loss=loss)
            else:
                observed = netfeed.observe_congestion(
                    topo, outs, overload=overload, capacity=cap_report,
                    loss=loss)
                for p in observed:
                    telemetry.send(("slow", int(p)), epoch)
                for leaf in range(topo.n_leaf):  # liveness heartbeats
                    telemetry.send(("hb", int(leaf)), epoch)
                n_sent = len(observed) + topo.n_leaf
                batch = telemetry.deliver(epoch)
                n_delivered = len(batch)
                n_admitted = n_stale = n_dup = 0
                admitted_slow: list[int] = []
                for payload, origin in batch:
                    if payload[0] == "slow":
                        verdict = health.admit_report(
                            int(payload[1]), origin, epoch)
                        if verdict == "admitted":
                            n_admitted += 1
                            admitted_slow.append(int(payload[1]))
                        elif verdict == "stale":
                            n_stale += 1
                        else:
                            n_dup += 1
                    else:  # heartbeat: same staleness gate, no health state
                        if staleness_bound is not None \
                                and epoch - origin > staleness_bound:
                            n_stale += 1
                        else:
                            n_admitted += 1
                watchdog.observe(n_admitted)
                slow = tuple(dict.fromkeys(admitted_slow))

            # ------------------------------------ versioned plan application
            next_plan = health.plan(epoch + 1, n_chunks=n_chunks,
                                    wire_dtype=wire_dtype)
            applied, took = collectives.apply_plan(plan, next_plan)
            if not took:
                plan_refused += 1  # a genuinely newer plan was refused: bug
            # the cross-version no-reordering invariant, asserted live: a
            # reordered (older) or duplicated delivery must be refused and
            # leave the applied table untouched
            stale_applied, took_stale = collectives.apply_plan(applied, plan)
            assert stale_applied is applied and not took_stale, \
                (applied.version, plan.version)
            dup_applied, took_dup = collectives.apply_plan(applied, applied)
            assert dup_applied is applied and not took_dup
            churn = sum(int(a != b)
                        for a, b in zip(plan.inactive, applied.inactive))
            fct, completion = metrics.fct_samples(result, trace,
                                                  horizon_s=duration_s)
            imb = metrics.throughput_imbalance(
                outs, sample_every=imbalance_sample_every,
                trace_stride=cfg.uplink_sample_every)
            rec = EpochRecord(
                epoch=epoch,
                fct_p50_s=float(np.percentile(fct, 50)),
                fct_p99_s=float(np.percentile(fct, 99)),
                fct_mean_s=float(fct.mean()),
                completion=completion,
                imbalance_mean=float(imb.mean()) if imb.size else 0.0,
                plan_churn=churn,
                quarantined=tuple(
                    p for p, d in enumerate(run_plan.inactive) if d),
                reported_slow=tuple(slow),
                spill_steps=int(result.spill_steps),
                new_builds=new_builds,
                fct=fct,
                imbalance=imb,
                replan_round=replan_round,
                straggler_scale=float(eff),
                straggler_quarantined=strag_quar,
                safe_mode=in_safe,
                plan_version=int(plan.version),
                reports_sent=n_sent,
                reports_delivered=n_delivered,
                reports_admitted=n_admitted,
                reports_stale=n_stale,
                reports_duplicate=n_dup,
                ff_steps=int(getattr(result, "ff_steps", 0)),
                insim=insim,
            )
            records.append(rec)
            plans.append(run_plan)
            if journal_fh is not None:
                import json

                journal_fh.write(json.dumps(dict(
                    epoch=epoch,
                    record=_rec_to_json(rec),
                    plan_inactive=[bool(b) for b in run_plan.inactive],
                    health=health.state(),
                    straggler=policy.state() if policy is not None else None,
                    telemetry=telemetry.state()
                    if telemetry is not None else None,
                    watchdog=watchdog.state()
                    if watchdog is not None else None,
                )) + "\n")
                journal_fh.flush()
            if fl is not None:
                fa = list(campaign.activations(epoch)) if campaign else []
                fa += [dict(kind="FaultEvent", links=list(ev.links),
                            scale=ev.scale, start_epoch=ev.start_epoch,
                            end_epoch=ev.end_epoch)
                       for ev in faults if ev.active(epoch)]
                fl.event(
                    "epoch", epoch=epoch, t0_s=t_ep,
                    dur_s=time.time() - t_ep, n_steps=n_steps,
                    fct_p50_us=round(rec.fct_p50_s * 1e6, 3),
                    fct_p99_us=round(rec.fct_p99_s * 1e6, 3),
                    completion=round(completion, 5),
                    plan_version=int(run_plan.version), plan_churn=churn,
                    safe_mode=in_safe, replan_round=replan_round,
                    quarantined=[int(p) for p in rec.quarantined],
                    reported_slow=[int(p) for p in rec.reported_slow],
                    straggler_quarantined=[int(i) for i in strag_quar],
                    straggler_scale=float(eff),
                    new_builds=new_builds,
                    spill_steps=int(result.spill_steps),
                    ff_steps=rec.ff_steps,
                    reports=None if telemetry is None else dict(
                        sent=n_sent, delivered=n_delivered,
                        admitted=n_admitted, stale=n_stale, duplicate=n_dup),
                    watchdog=watchdog.state() if watchdog is not None
                    else None,
                    sweep=sweep.obs_stats(),
                    hot_uplinks=netfeed.hot_uplinks(
                        topo, outs, capacity=cap_report),
                    faults=fa,
                    insim=insim,
                )
            plan = applied
        hist = CosimHistory(scheme=scheme, phi_steps=phi_steps,
                            duration_s=duration_s, records=records,
                            plans=plans, final_plan=plan, health=health,
                            plan_refused=plan_refused)
        if fl is not None and records:
            evs = list(faults) + (list(campaign.events) if campaign else [])
            fe = min((f.start_epoch for f in evs), default=1)
            fl.event(
                "run_end", epochs_run=len(records),
                convergence_epoch=hist.convergence_epoch(fe),
                plan_refused=plan_refused,
                total_new_builds=sum(r.new_builds for r in records),
                sweep=sweep.obs_stats())
    finally:
        if journal_fh is not None:
            journal_fh.close()
        if fl is not None and fl_owned:
            fl.close()
    return hist


def run_cosim_grid(specs: list[dict], *, workers: int | None = None,
                   salvage: bool = False, timeout_s: float | None = None,
                   retries: int = 0) -> list:
    """Fan a (scheme x ring size x fault schedule x seed) grid through the
    sweep runner's job pool: one ``run_cosim`` epoch loop per spec dict,
    dispatched by ``netsim.sweep.run_jobs`` (callable-job spelling), so
    grid points share the executable cache and the sharded dispatch path.
    Histories return in spec order.

    ``salvage`` / ``timeout_s`` / ``retries`` pass straight to
    ``sweep.run_jobs``: with ``salvage=True`` a chaos campaign that crashes
    or times out one grid cell yields a ``sweep.JobFailure`` poisoned
    record AT that cell's index (check ``getattr(h, "failed", False)``)
    instead of burning every completed sibling — exactly the crash-proof
    contract a 320-host fault sweep needs.

    Note: ``EpochRecord.new_builds`` attribution is per-process, so the
    no-recompile acceptance check should read a grid of ONE spec (or
    ``workers=1`` with distinct shapes) — concurrent grid points may
    interleave their builds."""
    from repro.netsim import sweep

    return sweep.run_jobs([functools.partial(run_cosim, **spec)
                           for spec in specs], workers=workers,
                          salvage=salvage, timeout_s=timeout_s,
                          retries=retries)
