"""Elastic path/pod health: phi-window quarantine, remesh planning,
straggler policy.

``LinkHealth`` is the host-side mirror of the paper's source-ToR Congestion
Table: a path reported slow stays quarantined for ``phi_steps`` training
steps (refreshing on every new report, exactly like the table's phi timer),
and ``plan()`` bakes the current quarantine set into a static
``PathPlan`` so the next grad sync routes around it.  Reports come from
wherever congestion is observed — straggling collective timings in a real
deployment, or the netsim fluid simulator through ``dist.netfeed`` in the
co-simulation loop.
"""
from __future__ import annotations

import dataclasses

from repro.dist import collectives


def alternating_directions(n_paths: int) -> tuple[int, ...]:
    """Default ring-direction assignment: adjacent paths run opposite ways
    so bidirectional host links are driven symmetrically."""
    return tuple(1 if p % 2 == 0 else -1 for p in range(n_paths))


@dataclasses.dataclass
class LinkHealth:
    """Per-path quarantine with a refreshing phi window (in steps).

    A path is inactive at ``step`` iff a slowness report arrived strictly
    fewer than ``phi_steps`` steps ago; each new report extends the window.

    Hysteresis (``cooldown_steps > 0``): a path that gets RE-reported
    within ``cooldown_steps`` of its window expiring is flapping —
    released, immediately slow again, re-quarantined, every cycle churning
    the plan.  Instead of re-entering at the base window, its effective phi
    DOUBLES (capped at ``max_phi_steps`` when > 0), so a flapper earns an
    exponentially longer quarantine while a genuinely recovered path (next
    report well after the cooldown) resets to the base ``phi_steps``.  The
    default ``cooldown_steps=0`` is bit-exact legacy behavior — the co-sim
    release-epoch contract (``expiry == last_report + phi_steps``) keys on
    it.

    Degraded-telemetry admission (``admit_report``): reports arriving over
    a lossy/delayed feedback channel are EPOCH-STAMPED at the observer and
    admitted against ``max_staleness_epochs`` — a report older than the
    bound is discarded (acting on ancient congestion state is how a
    balancer herds traffic onto a path that healed long ago), and a
    duplicated delivery of the same (path, origin) report is idempotent.
    ``max_staleness_epochs=None`` (the default) admits any age — the
    legacy perfect-channel contract."""

    n_paths: int
    phi_steps: int = 16
    directions: tuple[int, ...] | None = None
    cooldown_steps: int = 0
    max_phi_steps: int = 0  # 0 = uncapped
    max_staleness_epochs: int | None = None

    def __post_init__(self):
        assert self.n_paths >= 1 and self.phi_steps >= 1
        assert self.cooldown_steps >= 0 and self.max_phi_steps >= 0
        # a cap below the base window would let hysteresis SHORTEN
        # quarantines — the opposite of its contract
        assert self.max_phi_steps == 0 or self.max_phi_steps >= self.phi_steps
        assert self.max_staleness_epochs is None \
            or self.max_staleness_epochs >= 0
        if self.directions is None:
            self.directions = alternating_directions(self.n_paths)
        assert len(self.directions) == self.n_paths
        self._last_report: dict[int, int] = {}
        self._phi: dict[int, int] = {}  # per-path effective phi (hysteresis)
        self._seen: set[tuple[int, int]] = set()  # (path, origin) dedup

    def phi_of(self, path: int) -> int:
        """Effective phi window for ``path`` (== ``phi_steps`` unless
        hysteresis has extended it)."""
        return self._phi.get(path, self.phi_steps)

    def report_slow(self, path: int, step: int) -> None:
        assert 0 <= path < self.n_paths, path
        prev = self._last_report.get(path)
        if prev is not None and self.cooldown_steps > 0:
            prev_expiry = prev + self.phi_of(path)
            if prev_expiry <= step < prev_expiry + self.cooldown_steps:
                # released and slow again within the cooldown: a flapper —
                # double its window instead of churning the plan each cycle
                new_phi = self.phi_of(path) * 2
                if self.max_phi_steps > 0:
                    new_phi = min(new_phi, self.max_phi_steps)
                self._phi[path] = new_phi
            elif step >= prev_expiry + self.cooldown_steps:
                self._phi[path] = self.phi_steps  # clean recovery: reset
        self._last_report[path] = step if prev is None else max(prev, step)

    def admit_report(self, path: int, origin_epoch: int,
                     now_epoch: int) -> str:
        """Staleness-bounded, idempotent admission of one epoch-stamped
        report delivered at ``now_epoch`` about congestion OBSERVED at
        ``origin_epoch``.  Returns the verdict:

          * ``"stale"``     — older than ``max_staleness_epochs``; the
            report is discarded, no state changes (steering on it would
            chase a hotspot that may no longer exist);
          * ``"duplicate"`` — this exact (path, origin) report was already
            admitted; discarded, no state changes (a duplicated delivery
            must not refresh the phi window or trip flap hysteresis);
          * ``"admitted"``  — quarantine refreshes from the DELIVERY epoch
            (the staleness bound caps how far behind reality that is).

        Out-of-order deliveries are safe by construction: ``report_slow``
        keeps the max last-report step, so an older report arriving after
        a newer one can never shorten a window."""
        assert 0 <= origin_epoch <= now_epoch, (origin_epoch, now_epoch)
        if self.max_staleness_epochs is not None \
                and now_epoch - origin_epoch > self.max_staleness_epochs:
            return "stale"
        key = (path, origin_epoch)
        if key in self._seen:
            return "duplicate"
        self._seen.add(key)
        self.report_slow(path, now_epoch)
        return "admitted"

    def inactive(self, step: int) -> tuple[bool, ...]:
        return tuple(
            self._last_report.get(p) is not None
            and step < self._last_report[p] + self.phi_of(p)
            for p in range(self.n_paths)
        )

    def expiry(self, path: int) -> int | None:
        """First step at which ``path`` re-enters ``plan()`` — exactly
        its effective phi after its last report (each report refreshes the
        window).  None if the path was never reported.  The co-sim driver
        and the phi-expiry regression tests read this to assert quarantine
        release happens on the predicted epoch, not merely eventually."""
        last = self._last_report.get(path)
        return None if last is None else last + self.phi_of(path)

    def state(self) -> dict:
        """JSON-able snapshot for campaign journaling (``dist.cosim``)."""
        return dict(
            last_report={str(k): v for k, v in self._last_report.items()},
            phi={str(k): v for k, v in self._phi.items()},
            seen=sorted(list(k) for k in self._seen),
        )

    def restore(self, state: dict) -> None:
        self._last_report = {int(k): int(v)
                             for k, v in state.get("last_report", {}).items()}
        self._phi = {int(k): int(v) for k, v in state.get("phi", {}).items()}
        self._seen = {(int(p), int(e)) for p, e in state.get("seen", [])}

    def plan(self, step: int, n_chunks: int = 4, wire_dtype: str = "float32",
             version: int | None = None) -> collectives.PathPlan:
        """PathPlan avoiding currently quarantined paths.  ``version``
        defaults to ``step`` — successive planning epochs emit strictly
        increasing versions, the precondition of ``apply_plan``'s
        regression guard."""
        return collectives.PathPlan(
            n_chunks=n_chunks,
            directions=tuple(self.directions),
            inactive=self.inactive(step),
            wire_dtype=wire_dtype,
            version=step if version is None else version,
        )


# --------------------------------------------------- telemetry blackout
@dataclasses.dataclass
class TelemetryWatchdog:
    """Blackout detector for the congestion-feedback channel: after
    ``blackout_epochs`` consecutive planning epochs with ZERO admissible
    telemetry deliveries (congestion reports or liveness heartbeats), the
    planner must stop steering on its increasingly stale state and fall
    back to the conservative primary-path/ECMP default — a blind planner
    concentrating traffic around quarantines it can no longer verify is
    worse than no planner at all.  One admissible delivery recovers it.

    State machine (DESIGN.md §14): NORMAL --k silent epochs--> SAFE
    --any admissible delivery--> NORMAL.  ``observe`` returns the
    transition taken: "ok" / "silent" (counting down) / "safe" (in or
    entering safe mode) / "recovered"."""

    blackout_epochs: int = 3

    def __post_init__(self):
        assert self.blackout_epochs >= 1, self.blackout_epochs
        self._silent = 0
        self._safe = False
        # verdict counters for the flight log: how often each transition
        # fired over the whole run (safe-mode churn at a glance)
        self._transitions = {"ok": 0, "silent": 0, "safe": 0, "recovered": 0}

    @property
    def safe_mode(self) -> bool:
        return self._safe

    def silent_epochs(self) -> int:
        return self._silent

    def observe(self, n_admissible: int) -> str:
        """Feed one epoch's admissible-delivery count; returns the step
        taken ("ok" / "silent" / "safe" / "recovered")."""
        assert n_admissible >= 0, n_admissible
        if n_admissible > 0:
            self._silent = 0
            if self._safe:
                self._safe = False
                verdict = "recovered"
            else:
                verdict = "ok"
        else:
            self._silent += 1
            if self._silent >= self.blackout_epochs:
                self._safe = True
                verdict = "safe"
            else:
                verdict = "silent"
        self._transitions[verdict] += 1
        return verdict

    def state(self) -> dict:
        """JSON-able snapshot for campaign journaling (``dist.cosim``)."""
        return dict(silent=self._silent, safe=self._safe,
                    transitions=dict(self._transitions))

    def restore(self, state: dict) -> None:
        self._silent = int(state.get("silent", 0))
        self._safe = bool(state.get("safe", False))
        t = state.get("transitions")
        if t:
            self._transitions = {k: int(t.get(k, 0))
                                 for k in ("ok", "silent", "safe",
                                           "recovered")}


# ------------------------------------------------------------- pod remesh
@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    new_shape: tuple[int, ...]
    surviving_pods: tuple[int, ...]
    per_pod_batch_scale: float
    resume_step: int


def remesh_plan(mesh_shape: tuple[int, ...], failed_pods: tuple[int, ...],
                resume_step: int) -> RemeshPlan:
    """Shrink the pod axis around failed pods, keeping the global batch:
    each survivor picks up ``n_pods / n_survivors`` of the per-pod batch and
    training resumes from the last checkpoint at ``resume_step``."""
    n_pods = mesh_shape[0]
    failed = set(failed_pods)
    assert all(0 <= p < n_pods for p in failed), failed_pods
    surviving = tuple(p for p in range(n_pods) if p not in failed)
    if not surviving:
        raise RuntimeError(
            f"all {n_pods} pods failed — nothing to remesh onto")
    return RemeshPlan(
        new_shape=(len(surviving),) + tuple(mesh_shape[1:]),
        surviving_pods=surviving,
        per_pod_batch_scale=n_pods / len(surviving),
        resume_step=resume_step,
    )


# -------------------------------------------------------------- stragglers
@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler watchdog: ``max_misses`` consecutive
    over-deadline steps quarantine the rank; one on-time step recovers it.

    The co-sim driver (``dist.cosim``) feeds it per-rank step durations
    each epoch; the persistent ``quarantined()`` set tells the bulk-
    synchronous cadence which ranks to stop waiting for (a quarantined
    straggler no longer stretches everyone's step time)."""

    deadline_s: float
    max_misses: int = 3

    def __post_init__(self):
        assert self.deadline_s > 0 and self.max_misses >= 1
        self._misses: dict[int, int] = {}
        self._quarantined: set[int] = set()

    def observe(self, rank: int, step_duration_s: float) -> str:
        if step_duration_s <= self.deadline_s:
            self._misses[rank] = 0
            self._quarantined.discard(rank)  # one on-time step recovers
            return "ok"
        misses = self._misses.get(rank, 0) + 1
        self._misses[rank] = misses
        if misses >= self.max_misses:
            self._quarantined.add(rank)
            return "quarantine"
        return "warn"

    def misses(self, rank: int) -> int:
        return self._misses.get(rank, 0)

    def quarantined(self) -> tuple[int, ...]:
        return tuple(sorted(self._quarantined))

    def state(self) -> dict:
        """JSON-able snapshot for campaign journaling (``dist.cosim``)."""
        return dict(
            misses={str(k): v for k, v in self._misses.items()},
            quarantined=sorted(self._quarantined),
        )

    def restore(self, state: dict) -> None:
        self._misses = {int(k): int(v)
                        for k, v in state.get("misses", {}).items()}
        self._quarantined = {int(r) for r in state.get("quarantined", [])}
