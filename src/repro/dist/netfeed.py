"""Netsim -> dist feedback: close the loop between the fluid fabric
simulator and the training-side path planner.

The co-simulation cycle (DESIGN.md §11):

  1. a ``PathPlan`` is rendered into the ring-schedule traffic pattern it
     would put on the wire (``netsim.workloads.collective_trace``) — the
     paper's AI-training traffic mode, runnable under all five schemes on
     the sweep runner;
  2. the fluid sim runs it over a (possibly degraded) topology;
  3. ``report_congestion`` converts the sim's per-path offered-load /
     capacity statistics into ``LinkHealth.report_slow`` events — the same
     events a real deployment would derive from CNP counters or straggling
     chunk completions;
  4. ``LinkHealth.plan`` emits the next step's PathPlan, which now routes
     around the congested/failed paths.

Path identity mapping: on ``leaf_spine`` a ToR uplink IS a path (path p
crosses spine p); on ``three_tier`` uplink a fans out to the ``n_core``
paths (a, c) riding it, so an overloaded uplink quarantines all of them.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.dist.elastic import LinkHealth


def path_utilization(topo, outs, *, leaf: int | None = None,
                     capacity: np.ndarray | None = None) -> np.ndarray:
    """Time-mean offered-load / capacity ratio per ToR uplink.

    ``outs`` is the engine's StepOutputs (``uplink_load``: [T', L, S]
    offered bps, possibly window-averaged).  Returns [S] for one leaf or
    the per-uplink max over leaves (the planner cares about the worst
    source ToR using the path).  ``capacity`` overrides ``topo.capacity``
    (co-sim fault schedules evolve capacity per epoch without rebuilding
    the topology).

    A DEAD uplink (capacity ~0, e.g. a killed spine) reports +inf, not 0:
    offered load on it legitimately decays to zero once DCQCN chokes the
    victims, and dividing by the max(cap, 1) floor would then read the one
    unusable path as the IDLEST one — the planner would herd flows onto it.
    Deadness is decided on capacity, before the ratio.
    """
    up = np.asarray(outs.uplink_load)  # [T', L, S]
    cap_vec = np.asarray(topo.capacity if capacity is None else capacity)
    cap = cap_vec[np.asarray(topo.uplink_ids)]  # [L, S]
    util = np.where(cap <= 0.0, np.inf, up.mean(axis=0) / np.maximum(cap, 1.0))
    return util[leaf] if leaf is not None else util.max(axis=0)


def hot_uplinks(topo, outs, *, capacity: np.ndarray | None = None,
                top_n: int = 5) -> list[dict]:
    """The ``top_n`` busiest (leaf, spine) uplinks by time-mean utilization,
    as JSON-able dicts for the flight log: ``{"leaf", "uplink", "link",
    "util", "offered_gbps"}``, hottest first.  Dead uplinks (capacity ~0)
    report ``util`` as a large sentinel (1e6) rather than inf so the
    records stay strict-JSON parseable."""
    up = np.asarray(outs.uplink_load)  # [T', L, S]
    ids = np.asarray(topo.uplink_ids)  # [L, S]
    cap_vec = np.asarray(topo.capacity if capacity is None else capacity)
    cap = cap_vec[ids]
    offered = up.mean(axis=0)  # [L, S]
    util = np.where(cap <= 0.0, 1e6, offered / np.maximum(cap, 1.0))
    flat = np.argsort(util.ravel())[::-1][:top_n]
    out = []
    for k in flat:
        leaf, s = divmod(int(k), util.shape[1])
        out.append(dict(leaf=leaf, uplink=s, link=int(ids[leaf, s]),
                        util=round(float(util[leaf, s]), 6),
                        offered_gbps=round(float(offered[leaf, s]) / 1e9, 6)))
    return out


def _paths_for_uplink(topo, uplink: int) -> tuple[int, ...]:
    if topo.kind == "three_tier":
        n_core = topo.n_paths // topo.uplink_ids.shape[1]
        return tuple(uplink * n_core + c for c in range(n_core))
    return (uplink,)  # leaf_spine: uplink s <-> path s


def observe_congestion(topo, outs, *, leaf: int | None = None,
                       overload: float = 1.5,
                       dead_capacity_frac: float = 0.01,
                       capacity: np.ndarray | None = None,
                       loss: np.ndarray | None = None,
                       loss_threshold: float = 1e-3) -> tuple[int, ...]:
    """Pure observation: which paths does one simulation's per-path stats
    say are slow?  No health mutation — this is what the OBSERVER sees at
    the fabric, before the reports cross any (possibly lossy/delayed)
    telemetry channel back to the planner.  Returns the slow path ids
    (deduped, in report order, duplicates from overlapping uplink/loss
    rules collapsed).

    A path is slow when its uplink's time-mean offered load exceeded
    ``overload``x capacity (sustained congestion: the queue grew through
    the whole trace), or when the uplink's capacity itself is below
    ``dead_capacity_frac`` of the leaf-median (a failed/downed spine —
    offered load on a dead link may legitimately decay to zero once DCQCN
    chokes the victims, but the path is still unusable), or — with a
    ``loss`` vector (faults.LossyLink) — when any link on the path drops
    more than ``loss_threshold`` of packets: a lossy path murders goodput
    through go-back-N long before its utilization looks congested, the
    signal a deployment reads from retransmission counters.
    ``capacity`` overrides ``topo.capacity`` (the co-sim driver's per-epoch
    fault state)."""
    from repro.netsim.topology import paths_for_link

    util = path_utilization(topo, outs, leaf=leaf, capacity=capacity)
    cap_vec = np.asarray(topo.capacity if capacity is None else capacity)
    cap = cap_vec[np.asarray(topo.uplink_ids)]  # [L, S]
    cap = cap[leaf] if leaf is not None else cap.min(axis=0)
    dead = cap < dead_capacity_frac * np.median(cap)
    slow: list[int] = []
    for u in range(util.shape[0]):
        if util[u] > overload or dead[u]:
            slow.extend(_paths_for_uplink(topo, u))
    if loss is not None:
        lv = np.asarray(loss)
        for link in np.nonzero(lv[:topo.n_links] > loss_threshold)[0]:
            slow.extend(paths_for_link(topo, int(link)))
    return tuple(dict.fromkeys(slow))


def report_congestion(health: LinkHealth, topo, outs, *, step: int = 0,
                      leaf: int | None = None, overload: float = 1.5,
                      dead_capacity_frac: float = 0.01,
                      capacity: np.ndarray | None = None,
                      loss: np.ndarray | None = None,
                      loss_threshold: float = 1e-3) -> tuple[int, ...]:
    """Feed one simulation's per-path stats into ``health`` — the
    perfect-channel path: every slow path observed by
    ``observe_congestion`` lands in ``health.report_slow`` immediately, in
    order, exactly once (``report_slow`` is idempotent for same-step
    repeats, so the dedup is cosmetic).  The degraded-telemetry path in
    ``dist.cosim`` sends the SAME observation through a
    ``faults.TelemetryChannel`` and admits what survives via
    ``health.admit_report`` instead.  Returns the quarantined path ids."""
    assert health.n_paths == topo.n_paths, (health.n_paths, topo.n_paths)
    slow = observe_congestion(
        topo, outs, leaf=leaf, overload=overload,
        dead_capacity_frac=dead_capacity_frac, capacity=capacity,
        loss=loss, loss_threshold=loss_threshold)
    for p in slow:
        health.report_slow(p, step)
    return slow


@dataclasses.dataclass
class CoSimResult:
    result: object  # sweep CompactResult (finish / cnp_pkts / spill)
    outs: object  # StepOutputs
    health: LinkHealth
    slow_paths: tuple[int, ...]
    plan: object  # next-step PathPlan


def co_simulate(topo, plan, hosts, size_bytes: float, *, scheme: str = "ecmp",
                duration_s: float = 2e-3, health: LinkHealth | None = None,
                step: int = 0, overload: float = 1.5,
                capacity: np.ndarray | None = None,
                **cfg_kw) -> CoSimResult:
    """One full feedback cycle: plan -> trace -> sim -> health -> new plan.

    ``capacity`` overrides ``topo.capacity`` as the sweep's traced operand
    (a fault-schedule epoch); the multi-epoch loop lives in
    ``dist.cosim.run_cosim``.  Imports netsim lazily so ``repro.dist``
    stays importable without pulling the engine in (the subprocess
    collective tests don't need it).
    """
    from repro.netsim import sweep, workloads
    from repro.netsim.engine import SimConfig

    # healthy-uplink rate for the ring cadence: the median is immune to the
    # very degraded links the co-sim exists to detect (capacity[0] would be
    # leaf0-spine0 — exactly the link a killed-spine-0 scenario nukes)
    cap_vec = np.asarray(topo.capacity if capacity is None else capacity)
    link_bw = float(np.median(cap_vec[np.asarray(topo.uplink_ids)]))
    trace = workloads.collective_trace(plan, hosts, size_bytes, link_bw=link_bw)
    cfg = SimConfig(scheme=scheme, duration_s=duration_s, **cfg_kw)
    result, outs = sweep.run_one(topo, cfg, trace, capacity=capacity)
    if health is None:
        health = LinkHealth(n_paths=topo.n_paths,
                            directions=tuple(plan.directions)
                            if len(plan.directions) == topo.n_paths else None)
    slow = report_congestion(health, topo, outs, step=step, overload=overload,
                             capacity=capacity)
    new_plan = health.plan(step, n_chunks=plan.n_chunks,
                           wire_dtype=plan.wire_dtype)
    return CoSimResult(result=result, outs=outs, health=health,
                       slow_paths=slow, plan=new_plan)
