"""FSDP + TP sharding rules for the production meshes.

One shape-driven rule serves all ten architectures: for every parameter
matrix the largest dim divisible by the ``model`` axis takes tensor
parallelism and the largest *remaining* dim divisible by the ``data`` axis
takes FSDP — so no big matrix is ever fully replicated, and every
assignment divides evenly (validated against abstract 16x16 meshes in
tests/test_system.py without touching devices).  Vectors (norms, biases)
stay replicated; the ``pod`` axis is deliberately never used for params —
across pods the model is pure data-parallel and grad sync goes through
``dist.collectives`` (or one fat XLA all-reduce in the baseline mode).

Optimizer moments mirror param specs by construction (the dryrun builds
them with the same function), giving ZeRO-style sharded optimizer state.
"""
from __future__ import annotations

import numpy as np
from jax import tree as jtree
from jax.sharding import PartitionSpec as P

from repro.dist import _compat  # noqa: F401  (jax API shims)


def _axis_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _trim(assign: list) -> P:
    while assign and assign[-1] is None:
        assign.pop()
    return P(*assign)


def _matrix_spec(shape, data: int | None, model: int | None) -> P:
    if len(shape) < 2:
        return P()  # norms / biases / scalars: replicate
    order = sorted(range(len(shape)), key=lambda i: (-shape[i], i))
    assign: list = [None] * len(shape)
    mi = next((i for i in order if model and shape[i] % model == 0), None)
    if mi is not None:
        assign[mi] = "model"
    di = next((i for i in order if i != mi and data and shape[i] % data == 0), None)
    if di is not None:
        assign[di] = "data"
    return _trim(assign)


def param_specs(params, mesh):
    """PartitionSpec pytree for a parameter tree (arrays or ShapeDtypeStructs),
    same structure as ``params``."""
    sizes = _axis_sizes(mesh)
    data, model = sizes.get("data"), sizes.get("model")
    return jtree.map(lambda leaf: _matrix_spec(np.shape(leaf), data, model), params)


def batch_specs(batch, mesh):
    """Inputs shard their leading (global batch) dim over pod x data."""
    sizes = _axis_sizes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    n = int(np.prod([sizes[a] for a in dp], dtype=np.int64)) if dp else 1

    def spec(leaf):
        shape = np.shape(leaf)
        if not shape or n <= 1 or shape[0] % n:
            return P()
        return P(dp if len(dp) > 1 else dp[0])

    return jtree.map(spec, batch)


def cache_specs(cache, mesh):
    """KV / recurrent caches: batch dim over pod x data, plus TP on the
    first non-batch dim the model axis divides (heads, typically)."""
    sizes = _axis_sizes(mesh)
    model = sizes.get("model")
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    n = int(np.prod([sizes[a] for a in dp], dtype=np.int64)) if dp else 1

    def spec(leaf):
        shape = np.shape(leaf)
        assign: list = [None] * len(shape)
        if shape and n > 1 and shape[0] % n == 0:
            assign[0] = dp if len(dp) > 1 else dp[0]
        for i in range(1, len(shape)):
            if model and shape[i] % model == 0:
                assign[i] = "model"
                break
        return _trim(assign)

    return jtree.map(spec, cache)
