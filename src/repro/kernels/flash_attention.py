"""Pallas TPU flash attention (causal GQA, sliding window, softcap).

TPU-native tiling: queries stream through VMEM in ``block_q`` x ``hd``
tiles aligned to the MXU (block sizes multiples of 128 on hardware); K/V
rows for the (batch, kv-head) stay resident in VMEM and the kv dimension
is walked with an online-softmax fori_loop (running max m, normalizer l,
accumulator acc — the classic flash recurrence, fp32 accumulation).

Grid: (B * H, Sq / block_q).  GQA maps query head h to kv head h // G in
the BlockSpec index maps — no materialized head repetition.

Validated in interpret mode on CPU against kernels/ref.py over a
shape/dtype sweep (tests/test_kernels.py); ``ops.flash_attention`` is the
jit'd entry point the model layer can switch to on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len, causal, window,
    softcap, sm_scale,
):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # [block_q, hd]
    hd = q.shape[-1]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, hd), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    n_k = seq_len // block_k
    if causal:  # only kv blocks up to the diagonal contribute
        n_k = jnp.minimum(n_k, ((qi + 1) * block_q + block_k - 1) // block_k)

    def body(ki, carry):
        m, l, acc = carry
        # NOTE: the leading singleton must be a dslice — a bare int here
        # breaks the interpret-mode load discharge (no .shape on int).
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(ki * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(ki * block_k, block_k), slice(None)))[0]
        s = jnp.dot(q, k.astype(jnp.float32).T)  # [block_q, block_k]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v.astype(jnp.float32))
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, K, hd]
    v: jax.Array,  # [B, S, K, hd]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    sm_scale = hd**-0.5

    # head-major layout: [B*H, S, hd] queries; [B*K, S, hd] keys/values
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)

    grid = (B * H, S // block_q)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        b = bh // H
        h = bh % H
        return (b * K + h // G, 0, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q, block_k=block_k, seq_len=S, causal=causal,
            window=window, softcap=softcap, sm_scale=sm_scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, S, hd), kv_map),
            pl.BlockSpec((1, S, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
