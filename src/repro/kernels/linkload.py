"""Pallas TPU kernel for the switch dataplane step (netsim hot-spot).

Computes per-link offered load from (sub-flow -> link) incidence plus the
queue update and RED/ECN mark probabilities — the per-step work of every
ToR/spine in the fluid simulator.

TPU adaptation: the scatter-add over link ids is reformulated as a
ONE-HOT MATMUL so it runs on the MXU instead of serial scatter ports:
sub-flows stream through the grid in ``block_n`` tiles; for each tile the
kernel builds onehot[block_n, n_links] via broadcasted_iota comparison and
accumulates ``rates @ onehot`` into a VMEM-resident load vector.  Queue
and mark updates fuse into the final grid step (revisiting HBM zero
times).  n_links is padded to lanes (128).

Oracle: kernels/ref.py::linkload_ref (segment_sum formulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linkload_kernel(
    lid_ref, rate_ref, queue_ref, cap_ref, load_ref, newq_ref, mark_ref,
    *, n_links_padded, hops, kmin, kmax, pmax, dt,
):
    ti = pl.program_id(0)
    n_tiles = pl.num_programs(0)

    @pl.when(ti == 0)
    def _init():
        load_ref[...] = jnp.zeros_like(load_ref)

    lids = lid_ref[...]  # [block_n, hops] i32 (-1 = none)
    rates = rate_ref[...]  # [block_n]
    contrib = jnp.broadcast_to(rates[:, None], lids.shape).reshape(-1)  # [bn*hops]
    flat = lids.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], n_links_padded), 1)
    onehot = (iota == flat[:, None]).astype(jnp.float32)  # MXU-friendly
    load_ref[...] += contrib @ onehot  # [n_links_padded]

    @pl.when(ti == n_tiles - 1)
    def _finalize():
        load = load_ref[...]
        q = queue_ref[...]
        cap = cap_ref[...]
        newq = jnp.clip(q + (load - cap) * dt / 8.0, 0.0, 8e6)
        ramp = (newq - kmin) / (kmax - kmin)
        mark = jnp.where(newq < kmin, 0.0, jnp.where(newq > kmax, 1.0, ramp * pmax))
        newq_ref[...] = newq
        mark_ref[...] = mark


@functools.partial(
    jax.jit, static_argnames=("n_links", "kmin", "kmax", "pmax", "dt", "block_n", "interpret")
)
def linkload(
    link_ids: jax.Array,  # i32[n, hops]
    rates: jax.Array,  # f32[n]
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    *,
    n_links: int,
    kmin: float = 400e3,
    kmax: float = 1600e3,
    pmax: float = 0.2,
    dt: float = 10e-6,
    block_n: int = 512,
    interpret: bool = False,
):
    n, hops = link_ids.shape
    pad_n = (-n) % block_n
    if pad_n:
        link_ids = jnp.pad(link_ids, ((0, pad_n), (0, 0)), constant_values=-1)
        rates = jnp.pad(rates, (0, pad_n))
    L_pad = ((n_links + 127) // 128) * 128
    queue_p = jnp.pad(queue, (0, L_pad - n_links))
    cap_p = jnp.pad(capacity[:n_links], (0, L_pad - n_links), constant_values=1e30)

    grid = ((n + pad_n) // block_n,)
    load, newq, mark = pl.pallas_call(
        functools.partial(
            _linkload_kernel,
            n_links_padded=L_pad, hops=hops, kmin=kmin, kmax=kmax, pmax=pmax, dt=dt,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, hops), lambda t: (t, 0)),
            pl.BlockSpec((block_n,), lambda t: (t,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(link_ids, rates, queue_p, cap_p)
    return load[:n_links], newq[:n_links], mark[:n_links]
