"""Pallas TPU kernel for the switch dataplane step (netsim hot-spot).

Computes per-link offered load from (sub-flow -> link) incidence plus the
queue update and RED/ECN mark probabilities — the per-step work of every
ToR/spine in the fluid simulator.

TPU adaptation: the scatter-add over link ids is reformulated as a
ONE-HOT MATMUL so it runs on the MXU instead of serial scatter ports:
sub-flows stream through the grid in ``block_n`` tiles; for each tile the
kernel builds onehot[block_n, n_links] via broadcasted_iota comparison and
accumulates ``rates @ onehot`` into a VMEM-resident load vector.  Queue
and mark updates fuse into the final grid step (revisiting HBM zero
times).  n_links is padded to lanes (128).

Oracle: kernels/ref.py::linkload_ref (segment_sum formulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _linkload_kernel(
    lid_ref, rate_ref, queue_ref, cap_ref, load_ref, newq_ref, mark_ref,
    *, n_links_padded, hops, kmin, kmax, pmax, dt,
):
    ti = pl.program_id(0)
    n_tiles = pl.num_programs(0)

    @pl.when(ti == 0)
    def _init():
        load_ref[...] = jnp.zeros_like(load_ref)

    lids = lid_ref[...]  # [block_n, hops] i32 (-1 = none)
    rates = rate_ref[...]  # [block_n]
    contrib = jnp.broadcast_to(rates[:, None], lids.shape).reshape(-1)  # [bn*hops]
    flat = lids.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], n_links_padded), 1)
    onehot = (iota == flat[:, None]).astype(jnp.float32)  # MXU-friendly
    load_ref[...] += contrib @ onehot  # [n_links_padded]

    @pl.when(ti == n_tiles - 1)
    def _finalize():
        load = load_ref[...]
        q = queue_ref[...]
        cap = cap_ref[...]
        newq = jnp.clip(q + (load - cap) * dt / 8.0, 0.0, 8e6)
        ramp = (newq - kmin) / (kmax - kmin)
        mark = jnp.where(newq < kmin, 0.0, jnp.where(newq > kmax, 1.0, ramp * pmax))
        newq_ref[...] = newq
        mark_ref[...] = mark


@functools.partial(
    jax.jit, static_argnames=("n_links", "kmin", "kmax", "pmax", "dt", "block_n", "interpret")
)
def linkload(
    link_ids: jax.Array,  # i32[n, hops]
    rates: jax.Array,  # f32[n]
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    *,
    n_links: int,
    kmin: float = 400e3,
    kmax: float = 1600e3,
    pmax: float = 0.2,
    dt: float = 10e-6,
    block_n: int = 512,
    interpret: bool = False,
):
    n, hops = link_ids.shape
    pad_n = (-n) % block_n
    if pad_n:
        link_ids = jnp.pad(link_ids, ((0, pad_n), (0, 0)), constant_values=-1)
        rates = jnp.pad(rates, (0, pad_n))
    L_pad = ((n_links + 127) // 128) * 128
    queue_p = jnp.pad(queue, (0, L_pad - n_links))
    cap_p = jnp.pad(capacity[:n_links], (0, L_pad - n_links), constant_values=1e30)

    grid = ((n + pad_n) // block_n,)
    load, newq, mark = pl.pallas_call(
        functools.partial(
            _linkload_kernel,
            n_links_padded=L_pad, hops=hops, kmin=kmin, kmax=kmax, pmax=pmax, dt=dt,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, hops), lambda t: (t, 0)),
            pl.BlockSpec((block_n,), lambda t: (t,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
            pl.BlockSpec((L_pad,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(link_ids, rates, queue_p, cap_p)
    return load[:n_links], newq[:n_links], mark[:n_links]


def _cascade_kernel(
    lid_ref, rate_ref, queue_ref, cap_ref, qmask_ref,
    arrival_ref, newq_ref, mark_ref, scales_ref, thr_ref, r_ref,
    *, n_links_padded, hops, kmin, kmax, pmax, dt, qmax,
):
    """Fused hop cascade (netsim/dataplane.py).  Grid = (hops + 1, n_tiles),
    hop-major: pass ``h`` accumulates hop-h offered load over all flow tiles
    (one-hot matmul) into scales_ref[h], whose last tile converts it in place
    to the hop's capacity scale.  Each pass first advances the running
    per-flow rate (scratch ``r_ref``) by the PREVIOUS hop's scale — a second
    one-hot matmul doubling as the gather — so no hop ever re-reads HBM.
    The extra final pass (h == hops) applies the last scale to the rates
    (-> thr) and fuses the queue + RED mark update."""
    h = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    lids = lid_ref[...]  # [block_n, hops] i32 (sentinel = dummy column)
    bn = lids.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, n_links_padded), 1)
    hop_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, hops), 1)

    @pl.when((h == 0) & (t == 0))
    def _init():
        arrival_ref[...] = jnp.zeros_like(arrival_ref)

    # rate entering hop h = stored rate scaled by hop h-1 (one-hot gather)
    hprev = jnp.maximum(h - 1, 0)
    lid_prev = jnp.sum(jnp.where(hop_iota == hprev, lids, 0), axis=1)  # [bn]
    srow = pl.load(scales_ref, (pl.dslice(hprev, 1), slice(None)))[0]
    oh_prev = (iota == lid_prev[:, None]).astype(jnp.float32)
    stored = pl.load(r_ref, (pl.dslice(t, 1), slice(None)))[0]
    r = jnp.where(h == 0, rate_ref[...], stored * (oh_prev @ srow))
    pl.store(r_ref, (pl.dslice(t, 1), slice(None)), r[None])

    @pl.when(h < hops)
    def _accumulate():
        lid_h = jnp.sum(jnp.where(hop_iota == h, lids, 0), axis=1)
        oh = (iota == lid_h[:, None]).astype(jnp.float32)
        acc = pl.load(scales_ref, (pl.dslice(h, 1), slice(None)))[0]
        acc = jnp.where(t == 0, 0.0, acc)
        pl.store(scales_ref, (pl.dslice(h, 1), slice(None)), (acc + r @ oh)[None])

    @pl.when((h < hops) & (t == n_tiles - 1))
    def _finalize_hop():
        load = pl.load(scales_ref, (pl.dslice(h, 1), slice(None)))[0]
        arrival_ref[...] += load
        scale = jnp.minimum(1.0, cap_ref[...] / jnp.maximum(load, 1.0))
        pl.store(scales_ref, (pl.dslice(h, 1), slice(None)), scale[None])

    @pl.when(h == hops)
    def _write_thr():
        thr_ref[...] = r

    @pl.when((h == hops) & (t == n_tiles - 1))
    def _finalize():
        arr = arrival_ref[...]
        newq = jnp.clip(queue_ref[...] + (arr - cap_ref[...]) * dt / 8.0, 0.0, qmax)
        newq = newq * qmask_ref[...]
        ramp = (newq - kmin) / (kmax - kmin)
        mark = jnp.where(newq < kmin, 0.0, jnp.where(newq > kmax, 1.0, ramp * pmax))
        newq_ref[...] = newq
        mark_ref[...] = mark


def _cascade_tiered_kernel(
    fab_ref, tx_ref, rx_ref, rate_ref, queue_ref, cap_ref, qmask_ref,
    arrival_ref, newq_ref, mark_ref, scales_ref, thr_ref, r_ref,
    *, n_links_padded, n_sub, hf, kmin, kmax, pmax, dt, qmax,
):
    """NIC-tiered cascade (netsim/dataplane.cascade_nic).  Grid =
    (hf + 3, n_tiles), pass-major:

      pass 0        host_tx — the N sub-flows of a flow share the NIC, so
                    rates pre-reduce over N and the one-hot matmul runs at
                    [block_n, L] instead of [N*block_n, L]
      pass 1..hf    fabric hop p-1, per sub-flow (flat, as before)
      pass hf+1     host_rx — pre-reduced again
      pass hf+2     apply the rx scale -> thr, fuse queue + RED mark

    Each pass first advances the running [N, block_n] rate scratch by the
    PREVIOUS pass's scale (row-wise via tx for pass 1, per-sub-flow via the
    fabric one-hot for passes 2..hf+1, row-wise via rx for the final pass).
    scales_ref row p holds pass p's link load until the last tile converts
    it in place to the capacity scale."""
    p = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    lids = fab_ref[...]  # [N, block_n, hf] i32 (sentinel = dummy column)
    N, bn, _ = lids.shape
    flat_lids = lids.reshape(N * bn, hf)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (bn, n_links_padded), 1)
    iota_nb = jax.lax.broadcasted_iota(jnp.int32, (N * bn, n_links_padded), 1)
    hop_iota = jax.lax.broadcasted_iota(jnp.int32, (N * bn, hf), 1)
    oh_tx = (iota_b == tx_ref[...][:, None]).astype(jnp.float32)
    oh_rx = (iota_b == rx_ref[...][:, None]).astype(jnp.float32)

    @pl.when((p == 0) & (t == 0))
    def _init():
        arrival_ref[...] = jnp.zeros_like(arrival_ref)

    stored = pl.load(r_ref, (pl.dslice(t, 1), slice(None), slice(None)))[0]

    # ---- advance the running rates by the previous pass's scale ----
    @pl.when(p == 0)
    def _r_fresh():
        pl.store(r_ref, (pl.dslice(t, 1), slice(None), slice(None)),
                 rate_ref[...][None])

    @pl.when(p == 1)
    def _r_tx():
        s = oh_tx @ pl.load(scales_ref, (pl.dslice(0, 1), slice(None)))[0]
        pl.store(r_ref, (pl.dslice(t, 1), slice(None), slice(None)),
                 (stored * s[None, :])[None])

    @pl.when((p >= 2) & (p <= hf + 1))
    def _r_fab():
        hprev = jnp.clip(p - 2, 0, hf - 1)
        lid_prev = jnp.sum(jnp.where(hop_iota == hprev, flat_lids, 0), axis=1)
        oh = (iota_nb == lid_prev[:, None]).astype(jnp.float32)
        s = oh @ pl.load(scales_ref, (pl.dslice(p - 1, 1), slice(None)))[0]
        pl.store(r_ref, (pl.dslice(t, 1), slice(None), slice(None)),
                 (stored * s.reshape(N, bn))[None])

    r = pl.load(r_ref, (pl.dslice(t, 1), slice(None), slice(None)))[0]

    # ---- accumulate this pass's link load into scales_ref[p] ----
    def _acc(contrib):
        acc = pl.load(scales_ref, (pl.dslice(p, 1), slice(None)))[0]
        acc = jnp.where(t == 0, 0.0, acc)
        pl.store(scales_ref, (pl.dslice(p, 1), slice(None)), (acc + contrib)[None])

    @pl.when(p == 0)
    def _load_tx():
        _acc(jnp.sum(r, axis=0) @ oh_tx)

    @pl.when((p >= 1) & (p <= hf))
    def _load_fab():
        lid_h = jnp.sum(jnp.where(hop_iota == p - 1, flat_lids, 0), axis=1)
        oh = (iota_nb == lid_h[:, None]).astype(jnp.float32)
        _acc(r.reshape(N * bn) @ oh)

    @pl.when(p == hf + 1)
    def _load_rx():
        _acc(jnp.sum(r, axis=0) @ oh_rx)

    @pl.when((p <= hf + 1) & (t == n_tiles - 1))
    def _finalize_hop():
        load = pl.load(scales_ref, (pl.dslice(p, 1), slice(None)))[0]
        arrival_ref[...] += load
        scale = jnp.minimum(1.0, cap_ref[...] / jnp.maximum(load, 1.0))
        pl.store(scales_ref, (pl.dslice(p, 1), slice(None)), scale[None])

    @pl.when(p == hf + 2)
    def _write_thr():
        s = oh_rx @ pl.load(scales_ref, (pl.dslice(hf + 1, 1), slice(None)))[0]
        thr_ref[...] = r * s[None, :]

    @pl.when((p == hf + 2) & (t == n_tiles - 1))
    def _finalize():
        arr = arrival_ref[...]
        newq = jnp.clip(queue_ref[...] + (arr - cap_ref[...]) * dt / 8.0, 0.0, qmax)
        newq = newq * qmask_ref[...]
        ramp = (newq - kmin) / (kmax - kmin)
        mark = jnp.where(newq < kmin, 0.0, jnp.where(newq > kmax, 1.0, ramp * pmax))
        newq_ref[...] = newq
        mark_ref[...] = mark


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_links", "kmin", "kmax", "pmax", "dt", "qmax_bytes", "block_n", "interpret"
    ),
)
def linkload_cascade_tiered(
    fab_links: jax.Array,  # i32[n, N, hf]  (-1 = no hop)
    tx_link: jax.Array,  # i32[n]
    rx_link: jax.Array,  # i32[n]
    rates: jax.Array,  # f32[n, N]
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    queue_mask: jax.Array,  # f32[n_links]
    *,
    n_links: int,
    kmin: float = 400e3,
    kmax: float = 1600e3,
    pmax: float = 0.2,
    dt: float = 10e-6,
    qmax_bytes: float = 8e6,
    block_n: int = 512,
    interpret: bool = False,
):
    """NIC-tiered fused dataplane step: (arrival, new_queue, mark, thr[n, N]).
    Oracle: kernels/ref.py::linkload_cascade_tiered_ref."""
    n, n_sub, hf = fab_links.shape
    dummy = n_links
    fab = jnp.where(fab_links >= 0, fab_links, dummy).astype(jnp.int32)
    pad_n = (-n) % block_n
    if pad_n:
        fab = jnp.pad(fab, ((0, pad_n), (0, 0), (0, 0)), constant_values=dummy)
        tx_link = jnp.pad(tx_link, (0, pad_n), constant_values=dummy)
        rx_link = jnp.pad(rx_link, (0, pad_n), constant_values=dummy)
        rates = jnp.pad(rates, ((0, pad_n), (0, 0)))
    # sub-major layout: the scratch keeps block_n on the lane axis
    fab_t = jnp.swapaxes(fab, 0, 1)  # [N, n_pad, hf]
    rates_t = jnp.swapaxes(rates, 0, 1)  # [N, n_pad]
    L_pad = ((n_links + 1 + 127) // 128) * 128
    queue_p = jnp.pad(queue, (0, L_pad - n_links))
    cap_p = jnp.pad(capacity[:n_links], (0, L_pad - n_links), constant_values=1e30)
    qmask_p = jnp.pad(queue_mask[:n_links], (0, L_pad - n_links))

    n_tiles = (n + pad_n) // block_n
    grid = (hf + 3, n_tiles)
    arrival, newq, mark, scales, thr = pl.pallas_call(
        functools.partial(
            _cascade_tiered_kernel,
            n_links_padded=L_pad, n_sub=n_sub, hf=hf, kmin=kmin, kmax=kmax,
            pmax=pmax, dt=dt, qmax=qmax_bytes,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_sub, block_n, hf), lambda p, t: (0, t, 0)),
            pl.BlockSpec((block_n,), lambda p, t: (t,)),
            pl.BlockSpec((block_n,), lambda p, t: (t,)),
            pl.BlockSpec((n_sub, block_n), lambda p, t: (0, t)),
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
            pl.BlockSpec((L_pad,), lambda p, t: (0,)),
            pl.BlockSpec((hf + 2, L_pad), lambda p, t: (0, 0)),
            pl.BlockSpec((n_sub, block_n), lambda p, t: (0, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((hf + 2, L_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_sub, n + pad_n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_tiles, n_sub, block_n), jnp.float32)],
        interpret=interpret,
    )(fab_t, tx_link, rx_link, rates_t, queue_p, cap_p, qmask_p)
    return (
        arrival[:n_links], newq[:n_links], mark[:n_links],
        jnp.swapaxes(thr, 0, 1)[:n],
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_links", "kmin", "kmax", "pmax", "dt", "qmax_bytes", "block_n", "interpret"
    ),
)
def linkload_cascade(
    link_ids: jax.Array,  # i32[n, hops]  (-1 = no hop)
    rates: jax.Array,  # f32[n]
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    queue_mask: jax.Array,  # f32[n_links]
    *,
    n_links: int,
    kmin: float = 400e3,
    kmax: float = 1600e3,
    pmax: float = 0.2,
    dt: float = 10e-6,
    qmax_bytes: float = 8e6,
    block_n: int = 512,
    interpret: bool = False,
):
    """Fused dataplane step: (arrival, new_queue, mark, thr) — the whole
    offered-load -> queue -> RED/ECN pipeline of the fluid simulator in one
    kernel call.  Oracle: kernels/ref.py::linkload_cascade_ref."""
    n, hops = link_ids.shape
    dummy = n_links  # -1 hops land on the first padded column
    lid = jnp.where(link_ids >= 0, link_ids, dummy).astype(jnp.int32)
    pad_n = (-n) % block_n
    if pad_n:
        lid = jnp.pad(lid, ((0, pad_n), (0, 0)), constant_values=dummy)
        rates = jnp.pad(rates, (0, pad_n))
    L_pad = ((n_links + 1 + 127) // 128) * 128
    queue_p = jnp.pad(queue, (0, L_pad - n_links))
    cap_p = jnp.pad(capacity[:n_links], (0, L_pad - n_links), constant_values=1e30)
    qmask_p = jnp.pad(queue_mask[:n_links], (0, L_pad - n_links))

    n_tiles = (n + pad_n) // block_n
    grid = (hops + 1, n_tiles)
    arrival, newq, mark, scales, thr = pl.pallas_call(
        functools.partial(
            _cascade_kernel,
            n_links_padded=L_pad, hops=hops, kmin=kmin, kmax=kmax, pmax=pmax,
            dt=dt, qmax=qmax_bytes,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, hops), lambda h, t: (t, 0)),
            pl.BlockSpec((block_n,), lambda h, t: (t,)),
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
            pl.BlockSpec((L_pad,), lambda h, t: (0,)),
            pl.BlockSpec((hops, L_pad), lambda h, t: (0, 0)),
            pl.BlockSpec((block_n,), lambda h, t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((L_pad,), jnp.float32),
            jax.ShapeDtypeStruct((hops, L_pad), jnp.float32),
            jax.ShapeDtypeStruct((n + pad_n,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_tiles, block_n), jnp.float32)],
        interpret=interpret,
    )(lid, rates, queue_p, cap_p, qmask_p)
    return arrival[:n_links], newq[:n_links], mark[:n_links], thr[:n]
