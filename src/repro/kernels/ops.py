"""Jit'd kernel entry points: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa, linkload as _ll
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )


def linkload(link_ids, rates, queue, capacity, **kw):
    return _ll.linkload(link_ids, rates, queue, capacity,
                        interpret=not _on_tpu(), **kw)


flash_attention_ref = ref.flash_attention_ref
linkload_ref = ref.linkload_ref
