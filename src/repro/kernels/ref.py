"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, K, hd]
    v: jax.Array,  # [B, S, K, hd]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * (hd**-0.5)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def linkload_cascade_ref(
    link_ids: jax.Array,  # i32[n, hops]  (-1 = no hop)
    rates: jax.Array,  # f32[n]
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    queue_mask: jax.Array,  # f32[n_links] 0 on queueless (host_tx) links
    dt: float,
    qmax_bytes: float = 8e6,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(arrival, new_queue, mark_prob, thr) — the full hop-cascaded dataplane
    step (netsim/dataplane.py §9): hop h's arrivals are the upstream-scaled
    rates, queues integrate total arrival, RED marks on the new queue."""
    hops = link_ids.shape[1]
    cap_ext = jnp.concatenate([capacity, jnp.full((1,), 1e30, jnp.float32)])
    lid = jnp.where(link_ids >= 0, link_ids, n_links)
    r = rates
    arrival = jnp.zeros((n_links + 1,), jnp.float32)
    for h in range(hops):
        lh = lid[:, h]
        load_h = jax.ops.segment_sum(r, lh, num_segments=n_links + 1)
        arrival = arrival + load_h.at[n_links].set(0.0)
        s_h = jnp.minimum(1.0, cap_ext[lh] / jnp.maximum(load_h[lh], 1.0))
        r = r * jnp.where(link_ids[:, h] >= 0, s_h, 1.0)
    arrival = arrival[:n_links]
    new_queue = jnp.clip(queue + (arrival - capacity) * dt / 8.0, 0.0, qmax_bytes)
    new_queue = new_queue * queue_mask
    ramp = (new_queue - kmin) / (kmax - kmin)
    mark = jnp.where(new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax))
    return arrival, new_queue, mark.astype(jnp.float32), r


def linkload_cascade_tiered_ref(
    fab_links: jax.Array,  # i32[n, N, Hf]  (-1 = no hop)
    tx_link: jax.Array,  # i32[n]
    rx_link: jax.Array,  # i32[n]
    rates: jax.Array,  # f32[n, N]
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    queue: jax.Array,  # f32[n_links]
    capacity: jax.Array,  # f32[n_links]
    queue_mask: jax.Array,  # f32[n_links]
    dt: float,
    qmax_bytes: float = 8e6,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(arrival, new_queue, mark_prob, thr[n, N]) — the NIC-tiered cascade
    (netsim/dataplane.cascade_nic): host_tx/host_rx hops pre-reduce the N
    sub-flows sharing a NIC, fabric hops stay per sub-flow."""
    n, N, hf = fab_links.shape
    cap_ext = jnp.concatenate([capacity, jnp.full((1,), 1e30, jnp.float32)])
    r = rates  # [n, N]
    tx_load = jax.ops.segment_sum(r.sum(-1), tx_link, num_segments=n_links + 1)
    arrival = tx_load.at[n_links].set(0.0)
    s_tx = jnp.minimum(1.0, cap_ext / jnp.maximum(tx_load, 1.0))
    r = r * s_tx[tx_link][:, None]
    lid = jnp.where(fab_links >= 0, fab_links, n_links).reshape(-1, hf)
    rf = r.reshape(-1)
    for h in range(hf):
        lh = lid[:, h]
        load_h = jax.ops.segment_sum(rf, lh, num_segments=n_links + 1)
        arrival = arrival + load_h.at[n_links].set(0.0)
        s_h = jnp.minimum(1.0, cap_ext / jnp.maximum(load_h, 1.0))
        rf = rf * s_h[lh]
    r = rf.reshape(n, N)
    rx_load = jax.ops.segment_sum(r.sum(-1), rx_link, num_segments=n_links + 1)
    arrival = arrival + rx_load.at[n_links].set(0.0)
    s_rx = jnp.minimum(1.0, cap_ext / jnp.maximum(rx_load, 1.0))
    thr = r * s_rx[rx_link][:, None]
    arrival = arrival[:n_links]
    new_queue = jnp.clip(queue + (arrival - capacity) * dt / 8.0, 0.0, qmax_bytes)
    new_queue = new_queue * queue_mask
    ramp = (new_queue - kmin) / (kmax - kmin)
    mark = jnp.where(new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax))
    return arrival, new_queue, mark.astype(jnp.float32), thr


def linkload_ref(
    link_ids: jax.Array,  # i32[n, hops]  (-1 = no link)
    rates: jax.Array,  # f32[n]
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    queue: jax.Array,  # f32[n_links] current queue bytes
    capacity: jax.Array,  # f32[n_links]
    dt: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(load, new_queue, mark_prob) — the ToR/spine dataplane step."""
    hops = link_ids.shape[1]
    contrib = jnp.broadcast_to(rates[:, None], link_ids.shape).reshape(-1)
    lid = jnp.where(link_ids >= 0, link_ids, n_links).reshape(-1)
    load = jax.ops.segment_sum(contrib, lid, num_segments=n_links + 1)[:n_links]
    new_queue = jnp.clip(queue + (load - capacity) * dt / 8.0, 0.0, 8e6)
    ramp = (new_queue - kmin) / (kmax - kmin)
    mark = jnp.where(new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax))
    return load, new_queue, mark.astype(jnp.float32)
