"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, K, hd]
    v: jax.Array,  # [B, S, K, hd]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * (hd**-0.5)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def linkload_ref(
    link_ids: jax.Array,  # i32[n, hops]  (-1 = no link)
    rates: jax.Array,  # f32[n]
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    queue: jax.Array,  # f32[n_links] current queue bytes
    capacity: jax.Array,  # f32[n_links]
    dt: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(load, new_queue, mark_prob) — the ToR/spine dataplane step."""
    hops = link_ids.shape[1]
    contrib = jnp.broadcast_to(rates[:, None], link_ids.shape).reshape(-1)
    lid = jnp.where(link_ids >= 0, link_ids, n_links).reshape(-1)
    load = jax.ops.segment_sum(contrib, lid, num_segments=n_links + 1)[:n_links]
    new_queue = jnp.clip(queue + (load - capacity) * dt / 8.0, 0.0, 8e6)
    ramp = (new_queue - kmin) / (kmax - kmin)
    mark = jnp.where(new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax))
    return load, new_queue, mark.astype(jnp.float32)
