import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each live cell this lowers the real step function (train_step for
train_4k, prefill_step for prefill_32k, serve_step for decode shapes) with
production shardings on the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh, compiles it, and records:

  * memory_analysis  (bytes per device — proves the cell fits)
  * cost_analysis    (HLO flops / bytes accessed — roofline numerator)
  * collective bytes (parsed from the partitioned HLO: all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Results append to benchmarks/artifacts/dryrun_<mesh>.json, which
benchmarks/roofline.py turns into EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both [--grad-sync seqbalance]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.dist import collectives, sharding  # noqa: E402
from repro.launch import mesh as mesh_mod, steps  # noqa: E402
from repro.models import model  # noqa: E402
from repro.train import optimizer as opt_mod  # noqa: E402

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _first_shape_bytes(line: str) -> int:
    """Bytes of the result shape on an HLO op line (covers tuple results)."""
    total = 0
    head = line.split("=", 1)[0] if "=" in line else line
    # result type annotation sits right after '=' in HLO text: take the lhs
    # of the op call on the rhs instead (robust across printers):
    rhs = line.split("=", 1)[1] if "=" in line else line
    m = _SHAPE_RE.findall(rhs.split("(", 1)[0])
    for dt, dims in m:
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt[:4].rstrip("["), _DTYPE_BYTES.get(dt, 4))
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in partitioned HLO."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1].strip()
        body = rhs.split("(", 1)[0]
        for op in COLLECTIVE_OPS:
            # match op name at the start of the call (after shape annotation)
            if re.search(rf"\b{op}(-start|-done)?\(", rhs) or body.endswith(op):
                if f"{op}-done" in rhs:
                    continue  # avoid double counting async pairs
                out[op] += _first_shape_bytes(ls)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def _spec_tree_for_state(state_shapes, mesh):
    pspecs = sharding.param_specs(state_shapes["params"], mesh)
    opt = state_shapes["opt"]
    opt_specs = opt_mod.AdamWState(
        step=P(),
        mu=sharding.param_specs(opt.mu, mesh),
        nu=sharding.param_specs(opt.nu, mesh),
    )
    return {"params": pspecs, "opt": opt_specs}


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _lower_cell(cfg, shape, mesh, grad_sync):
    """Lower + compile one (cfg, shape) on ``mesh``; returns compiled."""
    batch_sds = registry.input_specs(cfg, shape)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if shape.kind == "train":
        state_sds = jax.eval_shape(lambda k: steps.init_state(k, cfg), key_sds)
        specs = _spec_tree_for_state(state_sds, mesh)
        b_specs = sharding.batch_specs(batch_sds, mesh)
        plan = collectives.PathPlan(n_chunks=4) if grad_sync == "seqbalance" else None
        step_fn = steps.make_train_step(cfg, opt_mod.AdamWConfig(), mesh, grad_sync, plan)
        jf = jax.jit(
            step_fn,
            in_shardings=(_named(specs, mesh), _named(b_specs, mesh)),
            out_shardings=(_named(specs, mesh), None),
            donate_argnums=(0,),
        )
        with mesh:
            return jf.lower(state_sds, batch_sds).compile()
    if shape.kind == "prefill":
        params_sds = jax.eval_shape(lambda k: model.init_params(k, cfg), key_sds)
        pspecs = sharding.param_specs(params_sds, mesh)
        b_specs = sharding.batch_specs(batch_sds, mesh)
        step_fn = steps.make_prefill_step(cfg, shape.seq_len)
        out_sds = jax.eval_shape(step_fn, params_sds, batch_sds)
        c_specs = sharding.cache_specs(out_sds[1], mesh)  # shard the cache!
        jf = jax.jit(
            step_fn,
            in_shardings=(_named(pspecs, mesh), _named(b_specs, mesh)),
            out_shardings=(None, _named(c_specs, mesh)),
        )
        with mesh:
            return jf.lower(params_sds, batch_sds).compile()
    # decode
    params_sds = jax.eval_shape(lambda k: model.init_params(k, cfg), key_sds)
    pspecs = sharding.param_specs(params_sds, mesh)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(None, cfg, shape.global_batch, shape.seq_len)
    )
    c_specs = sharding.cache_specs(cache_sds, mesh)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_specs = sharding.batch_specs({"tokens": tok_sds}, mesh)["tokens"]
    step_fn = steps.make_serve_step(cfg)
    jf = jax.jit(
        step_fn,
        in_shardings=(_named(pspecs, mesh), _named(t_specs, mesh), _named(c_specs, mesh)),
        out_shardings=(None, None, _named(c_specs, mesh)),
        donate_argnums=(2,),
    )
    with mesh:
        return jf.lower(params_sds, tok_sds, cache_sds).compile()


def _cell_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    get = (lambda k: float(cost.get(k, 0.0))) if isinstance(cost, dict) else (
        lambda k: float(getattr(cost, k.replace(" ", "_"), 0.0) or 0.0))
    return {
        "flops": get("flops"),
        "bytes": get("bytes accessed"),
        "coll": collective_bytes(compiled.as_text())["total"],
    }


def _depth_cfg(cfg, d: int):
    """Config with ``d`` superblocks (plus whisper's encoder scaled along)."""
    from repro.models.transformer import block_program

    _, _, n_super, _ = block_program(cfg)
    lps = cfg.n_layers // max(n_super, 1)
    kw = {"n_layers": d * lps}
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = d
    return cfg.replace(**kw), n_super, (cfg.n_layers % max(lps, 1)) / max(lps, 1)


def extrapolated_costs(cfg, shape, mesh, grad_sync) -> dict:
    """XLA's cost analysis counts a while-loop (scan) body ONCE; the true
    per-step cost is cost(outside) + n_super * cost(body).  Lower the model
    at depths 1 and 2 and extrapolate: cost(n) = c1 + (n-1+trail)*(c2-c1).
    (Methodology recorded in EXPERIMENTS.md §Dry-run.)"""
    cfg1, n_super, trail = _depth_cfg(cfg, 1)
    cfg2, _, _ = _depth_cfg(cfg, 2)
    c1 = _cell_costs(_lower_cell(cfg1, shape, mesh, grad_sync))
    c2 = _cell_costs(_lower_cell(cfg2, shape, mesh, grad_sync))
    scale = (n_super - 1) + trail
    return {
        k + "_x": c1[k] + scale * (c2[k] - c1[k]) for k in ("flops", "bytes", "coll")
    }


def run_cell(arch: str, shape_name: str, mesh, mesh_label: str, grad_sync: str = "xla",
             remat: str = "dots") -> dict:
    cfg = registry.get_config(arch).replace(remat=remat)
    shape = registry.get_shape(shape_name)
    ok, why = registry.cell_is_supported(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_label, "status": "SKIP",
                "reason": why}
    t0 = time.time()
    batch_sds = registry.input_specs(cfg, shape)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if shape.kind == "train":
        state_sds = jax.eval_shape(lambda k: steps.init_state(k, cfg), key_sds)
        specs = _spec_tree_for_state(state_sds, mesh)
        b_specs = sharding.batch_specs(batch_sds, mesh)
        plan = collectives.PathPlan(n_chunks=4) if grad_sync == "seqbalance" else None
        step_fn = steps.make_train_step(cfg, opt_mod.AdamWConfig(), mesh, grad_sync, plan)
        jf = jax.jit(
            step_fn,
            in_shardings=(_named(specs, mesh), _named(b_specs, mesh)),
            out_shardings=(_named(specs, mesh), None),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jf.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(lambda k: model.init_params(k, cfg), key_sds)
        pspecs = sharding.param_specs(params_sds, mesh)
        b_specs = sharding.batch_specs(batch_sds, mesh)
        step_fn = steps.make_prefill_step(cfg, shape.seq_len)
        out_sds = jax.eval_shape(step_fn, params_sds, batch_sds)
        c_specs = sharding.cache_specs(out_sds[1], mesh)  # shard the cache!
        jf = jax.jit(
            step_fn,
            in_shardings=(_named(pspecs, mesh), _named(b_specs, mesh)),
            out_shardings=(None, _named(c_specs, mesh)),
        )
        with mesh:
            lowered = jf.lower(params_sds, batch_sds)
    else:  # decode
        params_sds = jax.eval_shape(lambda k: model.init_params(k, cfg), key_sds)
        pspecs = sharding.param_specs(params_sds, mesh)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(None, cfg, shape.global_batch, shape.seq_len)
        )
        c_specs = sharding.cache_specs(cache_sds, mesh)
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        t_specs = sharding.batch_specs({"tokens": tok_sds}, mesh)["tokens"]
        step_fn = steps.make_serve_step(cfg)
        jf = jax.jit(
            step_fn,
            in_shardings=(_named(pspecs, mesh), _named(t_specs, mesh), _named(c_specs, mesh)),
            out_shardings=(None, None, _named(c_specs, mesh)),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jf.lower(params_sds, tok_sds, cache_sds)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    try:  # depth-extrapolated costs (scan bodies count once in XLA's CA)
        xcosts = extrapolated_costs(cfg, shape, mesh, grad_sync)
    except Exception as e:
        xcosts = {"flops_x": -1.0, "bytes_x": -1.0, "coll_x": -1.0,
                  "x_error": f"{type(e).__name__}: {e}"}

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    def g(obj, name, default=0.0):
        try:
            v = getattr(obj, name, None)
            if v is None and hasattr(obj, "get"):
                v = obj.get(name, default)
            return float(v) if v is not None else default
        except Exception:
            return default

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_label, "status": "OK",
        "grad_sync": grad_sync, "remat": remat,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "flops": g(cost, "flops") if not isinstance(cost, dict) else float(cost.get("flops", 0.0)),
        "bytes_accessed": g(cost, "bytes accessed")
        if not isinstance(cost, dict) else float(cost.get("bytes accessed", 0.0)),
        "argument_size_bytes": g(mem, "argument_size_in_bytes"),
        "output_size_bytes": g(mem, "output_size_in_bytes"),
        "temp_size_bytes": g(mem, "temp_size_in_bytes"),
        "peak_bytes": g(mem, "peak_memory_in_bytes"),
        "collectives": coll,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        **xcosts,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-sync", default="xla", choices=["xla", "seqbalance"])
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", mesh_mod.make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", mesh_mod.make_production_mesh(multi_pod=True)))

    cells = []
    if args.all:
        for arch, shape, ok, why in registry.list_cells(include_skipped=True):
            cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    for mesh_label, mesh in meshes:
        path = os.path.join(args.out, f"dryrun_{mesh_label}_{args.grad_sync}.json")
        existing = {}
        if os.path.exists(path):
            for r in json.load(open(path)):
                existing[(r["arch"], r["shape"])] = r
        for arch, shape_name in cells:
            if (arch, shape_name) in existing and existing[(arch, shape_name)]["status"] in ("OK", "SKIP"):
                print(f"[cached] {mesh_label} {arch} {shape_name}")
                continue
            print(f"[dryrun] {mesh_label} {arch} {shape_name} ...", flush=True)
            try:
                rec = run_cell(arch, shape_name, mesh, mesh_label, args.grad_sync, args.remat)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_label,
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            existing[(arch, shape_name)] = rec
            json.dump(list(existing.values()), open(path, "w"), indent=1)
            status = rec["status"]
            extra = ""
            if status == "OK":
                extra = (f" flops={rec['flops']:.3e} coll={rec['collectives']['total']:.3e}B"
                         f" peak={rec['peak_bytes']:.3e}B compile={rec['compile_s']}s")
            print(f"[{status}] {mesh_label} {arch} {shape_name}{extra}", flush=True)


if __name__ == "__main__":
    main()
