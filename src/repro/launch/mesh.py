"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see the
real single-device CPU).
"""
from __future__ import annotations

import jax

from repro.dist import _compat  # noqa: F401  (jax API shims for 0.4.x)


def _make(shape, axes):
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods over DCN for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh_like(shape: tuple[int, ...]):
    """Arbitrary dev-count meshes for tests/examples (e.g. (2,2,2) on 8
    host devices)."""
    axes = ("pod", "data", "model")[-len(shape):]
    return _make(shape, axes)


def make_pod_mesh(n_pods: int):
    """1-D pod-only mesh: every member is one pod gateway.  Used by the
    train driver's --grad-sync seqbalance mode, where the whole grad sync
    runs over the pod axis through dist.collectives."""
    return _make((n_pods,), ("pod",))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
