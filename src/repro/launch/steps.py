"""Step builders: train_step / prefill_step / serve_step for any arch.

Two grad-sync modes:
  * "xla"        — paper-baseline: params replicated over the pod axis,
    XLA inserts one fat all-reduce per gradient (the single-path elephant
    flow SeqBalance's motivation describes).
  * "seqbalance" — the pod-axis gradient sync runs through
    dist.collectives.seqbalance_all_reduce inside a partial-manual
    shard_map (manual over "pod", auto over data/model): N chunk rings on
    distinct directions, congestion-table-aware.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import collectives
from repro.models import model
from repro.train import optimizer as opt_mod


def make_train_step(cfg, opt_cfg: opt_mod.AdamWConfig, mesh=None, grad_sync: str = "xla",
                    plan: collectives.PathPlan | None = None):
    has_pod = mesh is not None and "pod" in mesh.axis_names and mesh.shape["pod"] > 1

    if grad_sync == "seqbalance" and has_pod:
        auto_axes = set(mesh.axis_names) - {"pod"}
        if auto_axes and getattr(jax.shard_map, "is_legacy_shim", False):
            # jax 0.4.x's experimental `auto=` partial-manual lowering
            # aborts the process inside the SPMD partitioner for this
            # program shape — fail at build time with a real signal instead
            raise NotImplementedError(
                "seqbalance grad sync over a multi-axis mesh (manual pod + "
                f"auto {sorted(auto_axes)}) needs jax>=0.5's native "
                "jax.shard_map; use a 1-D pod mesh (launch.mesh."
                "make_pod_mesh) or grad_sync='xla' on this toolchain")
        def train_step(state, batch):
            def per_pod(params, batch_shard):
                def lf(p):
                    return model.loss_fn(p, cfg, batch_shard)

                (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
                grads = collectives.tree_all_reduce_mean(grads, "pod", plan)
                loss = collectives.baseline_all_reduce(loss, "pod") / jax.lax.axis_size("pod")
                return loss, grads

            # manual over pod only; data/model stay auto (pjit semantics)
            pp = jax.shard_map(
                per_pod,
                mesh=mesh,
                in_specs=(P(), P("pod")),
                out_specs=(P(), P()),
                axis_names={"pod"},
                check_vma=False,
            )
            loss, grads = pp(state["params"], batch)
            new_p, new_opt, om = opt_mod.update(grads, state["opt"], state["params"], opt_cfg)
            return {"params": new_p, "opt": new_opt}, {"loss": loss, **om}

        return train_step

    def train_step(state, batch):
        def lf(p):
            return model.loss_fn(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        new_p, new_opt, om = opt_mod.update(grads, state["opt"], state["params"], opt_cfg)
        return {"params": new_p, "opt": new_opt}, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, max_len)

    return prefill_step


def make_serve_step(cfg):
    """One decode step: greedy next token against the KV cache."""

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, cfg, tokens, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return serve_step


def init_state(key, cfg):
    params = model.init_params(key, cfg)
    return {"params": params, "opt": opt_mod.init(params)}
