"""End-to-end training driver (runs on CPU with reduced configs, lowers to
the production mesh unchanged).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised: deterministic restart-safe data pipeline, AdamW +
cosine, checkpoint/resume (crash-safe atomic saves, async optional),
straggler watchdog, per-step metrics.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.data import pipeline
from repro.dist import collectives, elastic
from repro.launch import mesh as mesh_mod, steps
from repro.train import checkpoint, optimizer as opt_mod


def _cosim_plan(args):
    """--cosim-epochs: run the multi-epoch co-simulation loop on a pod
    fabric (one gateway host per pod, one pod per local device) and ship
    the converged PathPlan to the grad sync — the training side exercising
    the same plan -> fluid-sim -> quarantine -> plan cycle the netsim
    benches measure.  With --cosim-kill-spine the loop demonstrates the
    Fig. 11 round trip: the failed spine is quarantined while down and
    released phi epochs after it recovers."""
    from repro.dist import cosim
    from repro.netsim import topology

    n_ring = max(jax.local_device_count(), 2)
    topo = topology.leaf_spine(n_ring, 4, 1, 100e9)
    faults = ()
    if args.cosim_kill_spine >= 0:
        faults = (cosim.kill_spine(
            topo, args.cosim_kill_spine % topo.n_paths, epoch=1,
            recover_epoch=args.cosim_epochs // 2 + 1),)
    hist = cosim.run_cosim(
        topo, list(range(n_ring)), 8e6, scheme="ecmp",
        epochs=args.cosim_epochs, faults=faults, phi_steps=args.cosim_phi,
        n_chunks=args.n_chunks)
    for line in hist.summary_lines():
        print(f"[cosim] {line}")
    rebuilds = sum(r.new_builds for r in hist.records[1:])
    print(f"[cosim] final plan inactive={hist.final_plan.inactive} "
          f"rebuilds_after_first={rebuilds}")
    return hist.final_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-sync", default="xla", choices=["xla", "seqbalance"],
                    help="pod-axis gradient sync: one fat XLA all-reduce "
                         "(baseline) or the SeqBalance multipath chunk rings")
    ap.add_argument("--n-chunks", type=int, default=4,
                    help="seqbalance grad-sync chunk count")
    ap.add_argument("--cosim-epochs", type=int, default=0,
                    help="run this many plan->fluid-sim->health co-sim "
                         "epochs (dist.cosim) before training and seed the "
                         "grad-sync PathPlan from the converged plan")
    ap.add_argument("--cosim-kill-spine", type=int, default=1,
                    help="spine failed at co-sim epoch 1 (recovering at "
                         "epochs//2 + 1); -1 = healthy fabric")
    ap.add_argument("--cosim-phi", type=int, default=2,
                    help="co-sim quarantine window (planning epochs)")
    ap.add_argument("--cosim-only", action="store_true",
                    help="exit after the co-sim loop (CI smoke)")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq)
    ocfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                               total_steps=args.steps)

    # co-sim first: --cosim-only must exit before any model state (init
    # below materializes the full parameter + optimizer pytree, which at
    # granite-3-8b scale is not something a CI smoke should pay for)
    plan = collectives.PathPlan(n_chunks=args.n_chunks)
    if args.cosim_epochs > 0:
        plan = _cosim_plan(args)
        if args.cosim_only:
            return

    state = steps.init_state(jax.random.PRNGKey(0), cfg)
    start = 0
    if args.resume and args.ckpt_dir and (s := checkpoint.latest_step(args.ckpt_dir)) is not None:
        state = checkpoint.restore(args.ckpt_dir, s, jax.eval_shape(lambda: state))
        state = jax.tree.map(jax.numpy.asarray, state)
        start = s + 1
        print(f"[resume] from step {s}")

    mesh = None
    if args.grad_sync == "seqbalance":
        n_dev = jax.local_device_count()
        if n_dev > 1 and args.batch % n_dev == 0:
            # every local device is one "pod" gateway: the pod axis carries
            # the grad sync through dist.collectives, data/model stay local
            mesh = mesh_mod.make_pod_mesh(n_dev)
            print(f"[grad-sync] seqbalance over {n_dev}-way pod axis")
        else:
            print("[grad-sync] seqbalance needs >1 device and a batch the "
                  "device count divides — falling back to the XLA baseline")
    step_fn = jax.jit(steps.make_train_step(cfg, ocfg, mesh, args.grad_sync, plan))
    watchdog = elastic.StragglerPolicy(deadline_s=120.0)
    t_last = time.time()
    for i in range(start, args.steps):
        batch = pipeline.batch_at(dcfg, i)
        state, m = step_fn(state, batch)
        dt = time.time() - t_last
        t_last = time.time()
        watchdog.observe(0, dt)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} {dt:.2f}s")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, i, state, blocking=False)
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps - 1, state)
        print(f"[ckpt] final at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
