"""GQA attention with RoPE, optional qk-norm / softcap / sliding window,
full-sequence (train/prefill) and single-step (decode) paths.

The inner product kernel is the jnp reference by default; on TPU the Pallas
flash kernel (repro.kernels.flash_attention) can be enabled via
``use_pallas`` (validated against the same reference in tests).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class AttnParams(NamedTuple):
    ln: jax.Array  # [D]
    wq: jax.Array  # [D, H, hd]
    wk: jax.Array  # [D, K, hd]
    wv: jax.Array  # [D, K, hd]
    wo: jax.Array  # [H, hd, D]
    q_norm: jax.Array  # [hd] (qwen3 qk_norm; ones if unused)
    k_norm: jax.Array  # [hd]


class KVCache(NamedTuple):
    k: jax.Array  # [B, S_cache, K, hd]
    v: jax.Array  # [B, S_cache, K, hd]
    pos: jax.Array  # i32[] next write position (== #valid entries)


def init(key, cfg) -> AttnParams:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = common.split_keys(key, 4)
    return AttnParams(
        ln=jnp.zeros((D,), jnp.float32),
        wq=common.dense_init(ks[0], (D, H, hd), D),
        wk=common.dense_init(ks[1], (D, K, hd), D),
        wv=common.dense_init(ks[2], (D, K, hd), D),
        wo=common.dense_init(ks[3], (H, hd, D), H * hd),
        q_norm=jnp.zeros((hd,), jnp.float32),
        k_norm=jnp.zeros((hd,), jnp.float32),
    )


def _qkv(p: AttnParams, x, positions, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq.astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv.astype(dt))
    if cfg.qk_norm:
        q = common.rms_norm(q, p.q_norm)
        k = common.rms_norm(k, p.k_norm)
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q: [B,Sq,H,hd]; k/v: [B,Skv,K,hd]; mask: [B or 1, Sq, Skv] bool."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores * (hd**-0.5)
    scores = common.softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def causal_mask(Sq: int, window: int = 0) -> jax.Array:
    i = jnp.arange(Sq)[:, None]
    j = jnp.arange(Sq)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    return m[None]  # [1, Sq, Sq]


def full_mask(Sq: int, Skv: int) -> jax.Array:
    return jnp.ones((1, Sq, Skv), bool)


Q_CHUNK = 1024  # q-chunked attention above this sequence length


def _sdpa_chunked(q, k, v, cfg, window: int):
    """Causal attention, scanned over query chunks so the [Sq, Skv] score
    tensor never materializes (32k x 32k would be petabytes at batch) —
    the pure-JAX analogue of the flash kernel's outer loop."""
    B, S, H, hd = q.shape
    C = min(getattr(cfg, "q_chunk", Q_CHUNK) or Q_CHUNK, S)
    assert S % C == 0, (S, C)
    nch = S // C
    qs = q.reshape(B, nch, C, H, hd).swapaxes(0, 1)  # [nch, B, C, H, hd]
    j = jnp.arange(S)

    def chunk(carry, inp):
        ci, qc = inp
        i = ci * C + jnp.arange(C)
        m = j[None, :] <= i[:, None]
        if window:
            m &= (i[:, None] - j[None, :]) < window
        out = _sdpa(qc, k, v, m[None], cfg)
        return carry, out

    _, outs = jax.lax.scan(chunk, None, (jnp.arange(nch), qs))
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def apply_full(
    p: AttnParams, x, cfg, *, window: int = 0, is_causal: bool = True,
    kv_override=None,
):
    """Train/encoder path over the full sequence.  ``kv_override`` supplies
    cross-attention keys/values from an encoder (x only provides queries;
    no RoPE across modalities)."""
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    h = common.rms_norm(x, p.ln)
    if kv_override is None:
        q, k, v = _qkv(p, h, positions, cfg)
        if is_causal and S > Q_CHUNK:
            out = _sdpa_chunked(q, k, v, cfg, window)
        else:
            mask = causal_mask(S, window) if is_causal else full_mask(S, S)
            out = _sdpa(q, k, v, mask, cfg)
    else:
        dt = h.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, p.wq.astype(dt))
        if cfg.qk_norm:
            q = common.rms_norm(q, p.q_norm)
        k, v = kv_override
        out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), full_mask(S, k.shape[1]), cfg)
    return x + jnp.einsum("bqhk,hkd->bqd", out, p.wo.astype(x.dtype))


def encode_kv(p: AttnParams, enc_out, cfg):
    """Cross-attention K/V from encoder output (whisper decoder)."""
    dt = enc_out.dtype
    h = enc_out  # already normed by encoder final norm
    k = jnp.einsum("bsd,dhk->bshk", h, p.wk.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p.wv.astype(dt))
    if cfg.qk_norm:
        k = common.rms_norm(k, p.k_norm)
    return k, v


def init_cache(cfg, batch: int, max_len: int, dtype) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((batch, max_len, K, hd), dtype),
        v=jnp.zeros((batch, max_len, K, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def apply_prefill(p: AttnParams, x, cfg, cache: KVCache, *, window: int = 0):
    """Full-sequence forward that also fills the KV cache.  Windowed caches
    are ring buffers (slot = abs_position mod cache_len), so only the last
    ``cache_len`` positions are retained — half the memory of a full cache
    for local-attention layers."""
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    h = common.rms_norm(x, p.ln)
    q, k, v = _qkv(p, h, positions, cfg)
    if S > Q_CHUNK:
        out = _sdpa_chunked(q, k, v, cfg, window)
    else:
        out = _sdpa(q, k, v, causal_mask(S, window), cfg)
    y = x + jnp.einsum("bqhk,hkd->bqd", out, p.wo.astype(x.dtype))
    Sc = cache.k.shape[1]
    if S >= Sc:  # keep last Sc entries, ring-aligned
        ks = jnp.roll(k[:, -Sc:], S % Sc, axis=1)
        vs = jnp.roll(v[:, -Sc:], S % Sc, axis=1)
        new_cache = KVCache(
            k=ks.astype(cache.k.dtype), v=vs.astype(cache.v.dtype),
            pos=jnp.asarray(S, jnp.int32),
        )
    else:
        new_cache = KVCache(
            k=jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
            pos=jnp.asarray(S, jnp.int32),
        )
    return y, new_cache


def apply_decode(p: AttnParams, x, cfg, cache: KVCache, *, window: int = 0):
    """One-token step. x: [B, 1, D]; attends to cache + self.  The cache is
    a ring buffer when shorter than the absolute position horizon."""
    B, _, D = x.shape
    pos = cache.pos
    positions = pos[None, None]  # [1,1]
    h = common.rms_norm(x, p.ln)
    q, k, v = _qkv(p, h, jnp.broadcast_to(positions, (B, 1)), cfg)
    Sc = cache.k.shape[1]
    slot = pos % Sc
    kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    j = jnp.arange(Sc)[None, None, :]  # [1,1,Sc]
    age = (slot - j) % Sc  # steps since slot j was written (0 = current)
    abs_pos = pos - age
    mask = abs_pos >= 0
    if window:
        mask &= age < window
    out = _sdpa(q, kc.astype(q.dtype), vc.astype(q.dtype), mask, cfg)
    y = x + jnp.einsum("bqhk,hkd->bqd", out, p.wo.astype(x.dtype))
    return y, KVCache(k=kc, v=vc, pos=pos + 1)
