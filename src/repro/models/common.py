"""Shared model substrate: norms, RoPE, activations, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(kind: str):
    if kind == "gated_silu" or kind == "silu":
        return jax.nn.silu
    if kind == "gated_gelu" or kind == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if kind == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish), fp32 master weights."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def cast(params, dtype):
    return jax.tree.map(lambda p: p.astype(dtype), params)
