"""Dense MLP channel mixers: gated (SiLU/GELU) and squared-ReLU variants."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class MLPParams(NamedTuple):
    ln: jax.Array  # [D]
    wi: jax.Array  # [D, F]   (up / sole projection)
    wg: jax.Array  # [D, F]   (gate; zeros-shaped [D,0] when ungated)
    wo: jax.Array  # [F, D]


def init(key, cfg, d_ff: int | None = None) -> MLPParams:
    D = cfg.d_model
    F = cfg.d_ff if d_ff is None else d_ff
    gated = cfg.mlp_kind.startswith("gated")
    ks = common.split_keys(key, 3)
    return MLPParams(
        ln=jnp.zeros((D,), jnp.float32),
        wi=common.dense_init(ks[0], (D, F), D),
        wg=common.dense_init(ks[1], (D, F if gated else 0), D),
        wo=common.dense_init(ks[2], (F, D), F),
    )


def apply(p: MLPParams, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    h = common.rms_norm(x, p.ln)
    act = common.act_fn(cfg.mlp_kind)
    up = jnp.einsum("bsd,df->bsf", h, p.wi.astype(dt))
    if p.wg.shape[-1]:
        up = act(jnp.einsum("bsd,df->bsf", h, p.wg.astype(dt))) * up
    else:
        up = act(up)
    return x + jnp.einsum("bsf,fd->bsd", up, p.wo.astype(dt))
