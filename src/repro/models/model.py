"""Public model API: init / loss / prefill / decode for every assigned arch.

Params are a plain dict pytree; config is static.  The same functions serve
all ten architectures — the per-arch structure lives in
``transformer.block_program``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, transformer
from repro.models.transformer import Unit


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_params(key, cfg: ModelConfig) -> dict:
    ks = common.split_keys(key, 6)
    prelude, sb, n_super, trailing = transformer.block_program(cfg)
    params = {
        "embed": common.dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "prelude": tuple(transformer.unit_init(k, cfg, u)
                         for k, u in zip(common.split_keys(ks[1], max(len(prelude), 1)), prelude)),
        "main": transformer.init_stack(ks[2], cfg, sb, n_super),
        "trailing": tuple(transformer.unit_init(k, cfg, u)
                          for k, u in zip(common.split_keys(ks[3], max(len(trailing), 1)), trailing)),
    }
    if cfg.is_encoder_decoder:
        eu, en = transformer.encoder_program(cfg)
        params["encoder"] = transformer.init_stack(ks[4], cfg, eu, en)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(ks[5], (cfg.d_model, cfg.vocab), cfg.d_model)
    return params


# ------------------------------------------------------------------ inputs
def embed_tokens(params, cfg, tokens):
    x = params["embed"][tokens].astype(_dtype(cfg))
    return x * jnp.asarray(cfg.d_model**0.5, _dtype(cfg))


def _encoder_forward(params, cfg, frames):
    """Whisper-style encoder over stub frame embeddings [B, F, D]."""
    eu, en = transformer.encoder_program(cfg)
    x = frames.astype(_dtype(cfg))
    x, _, _ = transformer.apply_stack(params["encoder"], x, cfg, eu, en, "train")
    return common.rms_norm(x, params["enc_norm"])


def _decoder_input(params, cfg, batch):
    """Token embeddings, with modality stubs spliced in, plus ctx."""
    x = embed_tokens(params, cfg, batch["tokens"])
    ctx = {}
    if cfg.n_vision_tokens:  # VLM stub: patch embeddings replace the prefix
        vis = batch["vision_embeds"].astype(x.dtype)
        nv = cfg.n_vision_tokens
        x = jnp.concatenate([vis[:, :nv], x[:, nv:]], axis=1)
    if cfg.is_encoder_decoder:
        ctx["enc_out"] = _encoder_forward(params, cfg, batch["frames"])
    return x, ctx


def _run_decoder(params, cfg, x, mode, cache=None, ctx=None):
    prelude, sb, n_super, trailing = transformer.block_program(cfg)
    c_pre = cache["prelude"] if cache is not None else None
    c_main = cache["main"] if cache is not None else None
    c_trail = cache["trailing"] if cache is not None else None
    x, nc_pre, a0 = transformer.apply_units_unstacked(
        params["prelude"], x, cfg, prelude, mode, c_pre, ctx)
    x, nc_main, a1 = transformer.apply_stack(
        params["main"], x, cfg, sb, n_super, mode, c_main, ctx)
    x, nc_trail, a2 = transformer.apply_units_unstacked(
        params["trailing"], x, cfg, trailing, mode, c_trail, ctx)
    new_cache = {"prelude": nc_pre, "main": nc_main, "trailing": nc_trail}
    return x, new_cache, a0 + a1 + a2


def logits_fn(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return common.softcap(logits, cfg.logit_softcap)


# ------------------------------------------------------------------ train
def forward_train(params, cfg, batch):
    x, ctx = _decoder_input(params, cfg, batch)
    x, _, aux = _run_decoder(params, cfg, x, "train", ctx=ctx)
    x = common.rms_norm(x, params["final_norm"])
    return logits_fn(params, cfg, x), aux


def loss_fn(params, cfg, batch):
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / ntok + aux
    return loss, {"nll": nll.sum() / ntok, "aux": aux, "ntok": ntok}


# ------------------------------------------------------------------ serve
def init_cache(params, cfg, batch_size: int, max_len: int):
    prelude, sb, n_super, trailing = transformer.block_program(cfg)
    dt = _dtype(cfg)
    return {
        "prelude": tuple(transformer.unit_cache_init(cfg, u, batch_size, max_len, dt)
                         for u in prelude),
        "main": transformer.stack_cache_init(cfg, sb, n_super, batch_size, max_len, dt),
        "trailing": tuple(transformer.unit_cache_init(cfg, u, batch_size, max_len, dt)
                          for u in trailing),
    }


def prefill(params, cfg, batch, max_len: int):
    """Process the prompt; returns (last-position logits [B, V], cache)."""
    x, ctx = _decoder_input(params, cfg, batch)
    cache = init_cache(params, cfg, x.shape[0], max_len)
    x, cache, _ = _run_decoder(params, cfg, x, "prefill", cache=cache, ctx=ctx)
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    return logits_fn(params, cfg, x)[:, 0], cache


def decode_step(params, cfg, tokens, cache):
    """One decode step.  tokens: [B, 1] -> (logits [B, V], new cache)."""
    x = embed_tokens(params, cfg, tokens)
    x, cache, _ = _run_decoder(params, cfg, x, "decode", cache=cache)
    x = common.rms_norm(x, params["final_norm"])
    return logits_fn(params, cfg, x)[:, 0], cache
