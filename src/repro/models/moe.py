"""Fine-grained Mixture-of-Experts channel mixer (DeepSeekMoE / Granite-MoE).

Top-k routing with shared (always-on) experts.  Dispatch is the sort-based
fixed-shape algorithm: token replicas are bucketed per expert up to a
capacity C (overflow dropped, as in standard capacity-factor MoE), expert
FFNs run as one batched einsum over [E, C, D] — MXU-friendly, no dynamic
shapes, and the expert axis shards on "model" (expert parallelism); XLA
inserts the all-to-all at the dispatch/combine boundaries.

Aux losses: load-balance (Switch-style) + router z-loss, returned so the
train loop can add them.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class MoEParams(NamedTuple):
    ln: jax.Array  # [D]
    router: jax.Array  # [D, E]
    we_i: jax.Array  # [E, D, Fe]
    we_g: jax.Array  # [E, D, Fe]
    we_o: jax.Array  # [E, Fe, D]
    ws_i: jax.Array  # [D, Fs]  shared experts (Fs = n_shared * d_expert)
    ws_g: jax.Array  # [D, Fs]
    ws_o: jax.Array  # [Fs, D]


def init(key, cfg) -> MoEParams:
    D = cfg.d_model
    m = cfg.moe
    E, Fe = m.n_experts, m.d_expert
    Fs = m.n_shared * m.d_expert
    ks = common.split_keys(key, 7)
    return MoEParams(
        ln=jnp.zeros((D,), jnp.float32),
        router=common.dense_init(ks[0], (D, E), D),
        we_i=common.dense_init(ks[1], (E, D, Fe), D),
        we_g=common.dense_init(ks[2], (E, D, Fe), D),
        we_o=common.dense_init(ks[3], (E, Fe, D), Fe),
        ws_i=common.dense_init(ks[4], (D, Fs), D),
        ws_g=common.dense_init(ks[5], (D, Fs), D),
        ws_o=common.dense_init(ks[6], (Fs, D), Fs),
    )


def _capacity(T: int, E: int, k: int, cf: float) -> int:
    c = int(T * k * cf / E) + 1
    return max(8, min(c, T))


def apply(p: MoEParams, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    dt = x.dtype
    h = common.rms_norm(x, p.ln)
    flat = h.reshape(-1, D)  # [T, D]
    T = flat.shape[0]
    C = _capacity(T, E, k, m.capacity_factor)

    logits = jnp.einsum("td,de->te", flat.astype(jnp.float32), p.router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- fixed-shape sort-based dispatch -------------------------------
    e_flat = gate_idx.reshape(-1)  # [T*k]
    t_flat = jnp.repeat(jnp.arange(T), k)  # token id per replica
    w_flat = gate_vals.reshape(-1)
    order = jnp.argsort(e_flat)  # group replicas by expert
    e_sorted = e_flat[order]
    # position within the expert's group
    grp_start = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_in_grp = jnp.arange(T * k) - grp_start[e_sorted]
    keep = pos_in_grp < C
    slot = e_sorted * C + pos_in_grp  # [T*k] target slot (expert-major)
    slot = jnp.where(keep, slot, E * C)  # overflow -> dropped sentinel

    tok_of_slot = jnp.full((E * C + 1,), T, jnp.int32)  # sentinel token T
    tok_of_slot = tok_of_slot.at[slot].set(t_flat[order].astype(jnp.int32), mode="drop")
    w_of_slot = jnp.zeros((E * C + 1,), jnp.float32)
    w_of_slot = w_of_slot.at[slot].set(w_flat[order], mode="drop")

    flat_pad = jnp.concatenate([flat, jnp.zeros((1, D), dt)], axis=0)
    xe = flat_pad[tok_of_slot[: E * C]].reshape(E, C, D)  # [E, C, D]

    # ---- expert FFNs (expert-parallel einsums) -------------------------
    up = jnp.einsum("ecd,edf->ecf", xe, p.we_i.astype(dt))
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p.we_g.astype(dt)))
    ye = jnp.einsum("ecf,efd->ecd", gate * up, p.we_o.astype(dt))  # [E, C, D]

    # ---- combine back --------------------------------------------------
    ye_flat = ye.reshape(E * C, D) * w_of_slot[: E * C, None].astype(dt)
    out = jnp.zeros((T + 1, D), dt).at[tok_of_slot[: E * C]].add(ye_flat, mode="drop")
    out = out[:T]

    # ---- shared experts (dense) ----------------------------------------
    if p.ws_i.shape[-1]:
        su = jnp.einsum("td,df->tf", flat, p.ws_i.astype(dt))
        sg = jax.nn.silu(jnp.einsum("td,df->tf", flat, p.ws_g.astype(dt)))
        out = out + jnp.einsum("tf,fd->td", sg * su, p.ws_o.astype(dt))

    # ---- aux losses ------------------------------------------------------
    # Switch load-balance: E * sum_e (frac_tokens_e * mean_prob_e)
    assign1 = jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32)
    frac = assign1.mean(0)
    mean_prob = probs.mean(0)
    aux = dict(
        lb_loss=m.aux_loss * E * jnp.sum(frac * mean_prob),
        z_loss=m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
    )
    return x + out.reshape(B, S, D), aux
