"""Recurrent sequence mixers: mLSTM + sLSTM (xLSTM) and RG-LRU (Griffin /
RecurrentGemma).  TPU-adapted forms:

  mLSTM — matrix-memory cell with exponential gating.  The recurrence is
    linear in the state, so we run it CHUNKWISE-PARALLEL: within a chunk
    (256 tokens) everything is dense matmuls against a decay matrix (MXU
    work), across chunks a short lax.scan carries (C, n).  This is the
    TPU-native rethinking of the CUDA kernel in the paper — VMEM-sized
    chunks, MXU-shaped contractions — not a port of its per-timestep loop.
  sLSTM — scalar cell with hidden-state feedback through the gates; the
    recurrence is NOT associative, so it scans over time (documented
    bottleneck; xLSTM places sLSTM in 1-of-8 blocks for this reason).
  RG-LRU — diagonal linear recurrence with input-dependent gates; runs as
    a jax.lax.associative_scan (log-depth on TPU).

Decode paths update O(1)-size states — these archs are the ones that run
the ``long_500k`` cell (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common

MLSTM_CHUNK = 256


# ----------------------------------------------------------------- conv1d
def causal_conv1d(x: jax.Array, w: jax.Array, prev: jax.Array | None = None):
    """Depthwise causal conv.  x: [B,S,C], w: [W,C].  ``prev``: [B,W-1,C]
    carry-in for decode.  Returns (y, new_prev)."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # [B, S+W-1, C]
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    return y, xp[:, -(W - 1) :]


# ------------------------------------------------------------------ mLSTM
class MLSTMParams(NamedTuple):
    ln: jax.Array  # [D]
    w_up: jax.Array  # [D, P]   cell branch  (P = proj_factor * D)
    w_gate: jax.Array  # [D, P] output-gate branch
    conv_w: jax.Array  # [W, P]
    wq: jax.Array  # [P, H, hd]
    wk: jax.Array  # [P, H, hd]
    wv: jax.Array  # [P, H, hd]
    w_if: jax.Array  # [P, 2*H]  input/forget gate projections
    gn: jax.Array  # [H, hd] group-norm scale
    w_down: jax.Array  # [P, D]


class MLSTMCache(NamedTuple):
    C: jax.Array  # [B, H, hd, hd]
    n: jax.Array  # [B, H, hd]
    conv: jax.Array  # [B, W-1, P]


def mlstm_init(key, cfg) -> MLSTMParams:
    D = cfg.d_model
    P = int(cfg.mlstm_proj_factor * D)
    H = cfg.n_heads
    hd = P // H
    ks = common.split_keys(key, 7)
    return MLSTMParams(
        ln=jnp.zeros((D,), jnp.float32),
        w_up=common.dense_init(ks[0], (D, P), D),
        w_gate=common.dense_init(ks[1], (D, P), D),
        conv_w=common.dense_init(ks[2], (cfg.conv1d_width, P), cfg.conv1d_width),
        wq=common.dense_init(ks[3], (P, H, hd), P),
        wk=common.dense_init(ks[4], (P, H, hd), P),
        wv=common.dense_init(ks[5], (P, H, hd), P),
        w_if=common.dense_init(ks[6], (P, 2 * H), P),
        gn=jnp.ones((H, hd), jnp.float32),
        w_down=common.dense_init(ks[0], (P, D), P),
    )


def mlstm_cache_init(cfg, batch, dtype) -> MLSTMCache:
    D = cfg.d_model
    P = int(cfg.mlstm_proj_factor * D)
    H = cfg.n_heads
    hd = P // H
    return MLSTMCache(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, P), dtype),
    )


def _mlstm_gates(u, p, dt):
    """i (clamped exp) and log-f (log-sigmoid) gates.  u: [B,S,P]."""
    g = jnp.einsum("bsp,ph->bsh", u, p.w_if.astype(dt)).astype(jnp.float32)
    H = g.shape[-1] // 2
    i = jnp.exp(jnp.minimum(g[..., :H], 8.0))  # [B,S,H]
    logf = jax.nn.log_sigmoid(g[..., H:])  # <= 0
    return i, logf


def _mlstm_chunk(carry, inp, scale):
    """One chunk.  carry: (C [B,H,k,v], n [B,H,k]); inp: per-chunk tensors."""
    C0, n0 = carry
    q, k, v, i, logf = inp  # q,k,v: [B,L,H,hd] f32; i,logf: [B,L,H]
    b = jnp.cumsum(logf, axis=1)  # [B,L,H] cumulative log-decay
    bL = b[:, -1]  # [B,H]
    # decay matrix D[t,s] = exp(b_t - b_s) * i_s   (s<=t)
    L = q.shape[1]
    dmat = b[:, :, None, :] - b[:, None, :, :]  # [B,t,s,H]
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
    dmat = jnp.where(tri, jnp.exp(dmat) * i[:, None, :, :], 0.0)  # [B,t,s,H]
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * scale * dmat
    intra = jnp.einsum("btsh,bshd->bthd", scores, v)
    inter = jnp.exp(b)[..., None] * jnp.einsum("bthd,bhdk->bthk", q, C0)
    n_t = jnp.exp(b)[..., None] * n0[:, None] + jnp.einsum("btsh,bshd->bthd", dmat, k)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_t)), 1.0)
    h = (intra + inter) / denom[..., None]  # [B,L,H,hd]
    # state update to end of chunk
    kdec = jnp.exp(bL[:, None] - b) [..., None] * (i[..., None] * k)  # [B,L,H,hd]
    C1 = jnp.exp(bL)[..., None, None] * C0 + jnp.einsum("blhk,blhv->bhkv", kdec, v)
    n1 = jnp.exp(bL)[..., None] * n0 + kdec.sum(1)
    return (C1, n1), h


def mlstm_apply(p: MLSTMParams, x, cfg, cache: MLSTMCache | None = None, decode=False):
    """Full-sequence (chunkwise) or single-step (decode) mLSTM block."""
    B, S, D = x.shape
    dt = x.dtype
    h_in = common.rms_norm(x, p.ln)
    u = jnp.einsum("bsd,dp->bsp", h_in, p.w_up.astype(dt))
    z = jnp.einsum("bsd,dp->bsp", h_in, p.w_gate.astype(dt))
    conv_prev = cache.conv if cache is not None else None
    uc, conv_new = causal_conv1d(u, p.conv_w.astype(dt), conv_prev)
    uc = jax.nn.silu(uc)
    H = p.wq.shape[1]
    hd = p.wq.shape[2]
    q = jnp.einsum("bsp,phk->bshk", uc, p.wq.astype(dt)).astype(jnp.float32)
    k = jnp.einsum("bsp,phk->bshk", uc, p.wk.astype(dt)).astype(jnp.float32)
    v = jnp.einsum("bsp,phk->bshk", u, p.wv.astype(dt)).astype(jnp.float32)
    i, logf = _mlstm_gates(uc, p, dt)
    scale = hd**-0.5

    C0 = cache.C if cache is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = cache.n if cache is not None else jnp.zeros((B, H, hd), jnp.float32)

    if decode:  # S == 1 single step
        f1 = jnp.exp(logf[:, 0])  # [B,H]
        C1 = f1[..., None, None] * C0 + (i[:, 0, :, None] * k[:, 0])[..., :, None] * v[:, 0][..., None, :]
        n1 = f1[..., None] * n0 + i[:, 0, :, None] * k[:, 0]
        num = jnp.einsum("bhd,bhdk->bhk", q[:, 0] * scale, C1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, 0] * scale, n1)), 1.0)
        h = (num / den[..., None])[:, None]  # [B,1,H,hd]
        new_cache = MLSTMCache(C=C1, n=n1, conv=conv_new)
    else:
        L = min(MLSTM_CHUNK, S)
        assert S % L == 0, (S, L)
        nch = S // L
        resh = lambda a: a.reshape(B, nch, L, *a.shape[2:]).swapaxes(0, 1)
        (C1, n1), hs = jax.lax.scan(
            lambda c, t: _mlstm_chunk(c, t, scale), (C0, n0),
            (resh(q), resh(k), resh(v), resh(i), resh(logf)),
        )
        h = hs.swapaxes(0, 1).reshape(B, S, H, hd)
        new_cache = MLSTMCache(C=C1, n=n1, conv=conv_new)

    h = common.rms_norm(h.astype(dt), p.gn - 1.0)  # per-head group norm
    out = (h.reshape(B, S, -1) * jax.nn.silu(z)).astype(dt)
    return x + jnp.einsum("bsp,pd->bsd", out, p.w_down.astype(dt)), new_cache


# ------------------------------------------------------------------ sLSTM
class SLSTMParams(NamedTuple):
    ln: jax.Array  # [D]
    w: jax.Array  # [D, H, 4, hd]  (i, f, z, o projections)
    r: jax.Array  # [H, hd, 4, hd] recurrent (block-diagonal per head)
    b: jax.Array  # [H, 4, hd]
    gn: jax.Array  # [H, hd]
    w_up1: jax.Array  # [D, F]  post-cell gated FFN (proj_factor 4/3)
    w_up2: jax.Array  # [D, F]
    w_down: jax.Array  # [F, D]


class SLSTMCache(NamedTuple):
    c: jax.Array  # [B, H, hd]
    n: jax.Array  # [B, H, hd]
    h: jax.Array  # [B, H, hd]
    m: jax.Array  # [B, H, hd] stabilizer


def slstm_init(key, cfg) -> SLSTMParams:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    F = int(cfg.slstm_proj_factor * D)
    ks = common.split_keys(key, 5)
    return SLSTMParams(
        ln=jnp.zeros((D,), jnp.float32),
        w=common.dense_init(ks[0], (D, H, 4, hd), D),
        r=common.dense_init(ks[1], (H, hd, 4, hd), D // H),
        b=jnp.zeros((H, 4, hd), jnp.float32),
        gn=jnp.ones((H, hd), jnp.float32),
        w_up1=common.dense_init(ks[2], (D, F), D),
        w_up2=common.dense_init(ks[3], (D, F), D),
        w_down=common.dense_init(ks[4], (F, D), F),
    )


def slstm_cache_init(cfg, batch, dtype) -> SLSTMCache:
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMCache(c=z(), n=z(), h=z(), m=z() - 10.0)


def _slstm_cell(state: SLSTMCache, gx, r):
    """gx: [B,H,4,hd] pre-activations from input; r: recurrent weights."""
    c, n, h, m = state
    g = gx + jnp.einsum("bhd,hdgk->bhgk", h, r)
    it, ft, zt, ot = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zt)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMCache(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm_apply(p: SLSTMParams, x, cfg, cache: SLSTMCache | None = None, decode=False):
    B, S, D = x.shape
    dt = x.dtype
    h_in = common.rms_norm(x, p.ln)
    gx = jnp.einsum("bsd,dhgk->bshgk", h_in, p.w.astype(dt)).astype(jnp.float32)
    gx = gx + p.b
    r = p.r.astype(jnp.float32)
    state = cache if cache is not None else slstm_cache_init(cfg, B, dt)

    if decode:
        state = _slstm_cell(state, gx[:, 0], r)
        hs = state.h[:, None]  # [B,1,H,hd]
    else:
        def step(st, g):
            st = _slstm_cell(st, g, r)
            return st, st.h

        state, hs = jax.lax.scan(step, state, gx.swapaxes(0, 1))
        hs = hs.swapaxes(0, 1)  # [B,S,H,hd]

    hs = common.rms_norm(hs.astype(dt), p.gn - 1.0).reshape(B, S, D)
    up = jax.nn.silu(jnp.einsum("bsd,df->bsf", hs, p.w_up1.astype(dt)))
    up = up * jnp.einsum("bsd,df->bsf", hs, p.w_up2.astype(dt))
    return x + jnp.einsum("bsf,fd->bsd", up, p.w_down.astype(dt)), state


# ------------------------------------------------------------------ RG-LRU
class RGLRUParams(NamedTuple):
    ln: jax.Array  # [D]
    w_in: jax.Array  # [D, R]
    w_gate: jax.Array  # [D, R]
    conv_w: jax.Array  # [W, R]
    w_rg: jax.Array  # [R, R] recurrence gate proj
    w_ig: jax.Array  # [R, R] input gate proj
    lam: jax.Array  # [R] Lambda (a = sigmoid(lam))
    w_out: jax.Array  # [R, D]


class RGLRUCache(NamedTuple):
    h: jax.Array  # [B, R] f32
    conv: jax.Array  # [B, W-1, R]


def rglru_init(key, cfg) -> RGLRUParams:
    D = cfg.d_model
    R = cfg.rglru_width or cfg.d_model
    ks = common.split_keys(key, 6)
    # Lambda init so a^c in [0.9, 0.999]-ish
    lam = jnp.log(jnp.linspace(0.9, 0.999, R) / (1 - jnp.linspace(0.9, 0.999, R)))
    return RGLRUParams(
        ln=jnp.zeros((D,), jnp.float32),
        w_in=common.dense_init(ks[0], (D, R), D),
        w_gate=common.dense_init(ks[1], (D, R), D),
        conv_w=common.dense_init(ks[2], (cfg.conv1d_width, R), cfg.conv1d_width),
        w_rg=common.dense_init(ks[3], (R, R), R),
        w_ig=common.dense_init(ks[4], (R, R), R),
        lam=lam.astype(jnp.float32),
        w_out=common.dense_init(ks[5], (R, D), R),
    )


def rglru_cache_init(cfg, batch, dtype) -> RGLRUCache:
    R = cfg.rglru_width or cfg.d_model
    return RGLRUCache(
        h=jnp.zeros((batch, R), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, R), dtype),
    )


_RGLRU_C = 8.0


def _rglru_coeffs(xc, p, dt):
    """a_t, b_t of h_t = a_t h + b_t.  xc: [B,S,R] conv'd input branch."""
    rg = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p.w_rg.astype(dt)).astype(jnp.float32))
    ig = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p.w_ig.astype(dt)).astype(jnp.float32))
    log_a = -_RGLRU_C * rg * jax.nn.softplus(p.lam)  # <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * ig * xc.astype(jnp.float32)
    return a, b


def rglru_apply(p: RGLRUParams, x, cfg, cache: RGLRUCache | None = None, decode=False):
    B, S, D = x.shape
    dt = x.dtype
    hx = common.rms_norm(x, p.ln)
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", hx, p.w_gate.astype(dt)), approximate=True)
    xin = jnp.einsum("bsd,dr->bsr", hx, p.w_in.astype(dt))
    conv_prev = cache.conv if cache is not None else None
    xc, conv_new = causal_conv1d(xin, p.conv_w.astype(dt), conv_prev)
    a, b = _rglru_coeffs(xc, p, dt)  # [B,S,R] f32
    h0 = cache.h if cache is not None else jnp.zeros((B, a.shape[-1]), jnp.float32)

    if decode:
        h1 = a[:, 0] * h0 + b[:, 0]
        hs = h1[:, None]
        new_cache = RGLRUCache(h=h1, conv=conv_new)
    else:
        # prepend the carry as a pseudo-step, associative scan, drop it
        a_full = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b_full = jnp.concatenate([h0[:, None], b], axis=1)

        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        _, hs_full = jax.lax.associative_scan(comb, (a_full, b_full), axis=1)
        hs = hs_full[:, 1:]
        new_cache = RGLRUCache(h=hs[:, -1], conv=conv_new)

    out = (hs.astype(dt) * gate)
    return x + jnp.einsum("bsr,rd->bsd", out, p.w_out.astype(dt)), new_cache
