"""Layer-stack machinery: heterogeneous superblocks under lax.scan.

Every architecture is a *program*: an optional prelude, a repeating
superblock (scanned ``n_super`` times — keeps HLO size O(1) in depth, the
MaxText idiom), and an optional trailing partial block.  A superblock is a
tuple of Units (attn / cross / mlp / moe / mlstm / slstm / rglru); per-unit
params are stacked on a leading [n_super] axis, likewise caches, so scan
carries stay homogeneous even for mixed-kind stacks (xLSTM's 7:1
mLSTM/sLSTM blocks, RecurrentGemma's R-R-A pattern, Gemma2's local/global
alternation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, mlp, moe, recurrent


@dataclasses.dataclass(frozen=True)
class Unit:
    kind: str  # attn | cross | mlp | moe | mlstm | slstm | rglru
    window: int = 0
    causal: bool = True


def block_program(cfg):
    """(prelude, superblock, n_super, trailing) of Units for the decoder
    stack (the encoder stack, if any, is uniform and built separately)."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_alternate:
            sb = (
                Unit("attn", window=cfg.local_window),
                Unit("mlp"),
                Unit("attn"),
                Unit("mlp"),
            )
            assert cfg.n_layers % 2 == 0
            return (), sb, cfg.n_layers // 2, ()
        return (), (Unit("attn"), Unit("mlp")), cfg.n_layers, ()
    if fam == "moe":
        return (), (Unit("attn"), Unit("moe")), cfg.n_layers, ()
    if fam == "ssm":  # xLSTM 7:1
        sb = tuple(Unit("mlstm") for _ in range(7)) + (Unit("slstm"),)
        assert cfg.n_layers % 8 == 0
        return (), sb, cfg.n_layers // 8, ()
    if fam == "hybrid":  # RecurrentGemma (R, R, A) + MLP after each mixer
        sb = (
            Unit("rglru"), Unit("mlp"),
            Unit("rglru"), Unit("mlp"),
            Unit("attn", window=cfg.local_window), Unit("mlp"),
        )
        n = cfg.n_layers // 3
        rem = cfg.n_layers % 3
        trailing = (Unit("rglru"), Unit("mlp")) * rem
        return (), sb, n, trailing
    if fam == "audio":  # whisper decoder
        return (), (Unit("attn"), Unit("cross", causal=False), Unit("mlp")), cfg.n_layers, ()
    raise ValueError(fam)


def encoder_program(cfg):
    return (Unit("attn", causal=False), Unit("mlp")), cfg.n_encoder_layers


# --------------------------------------------------------------- dispatch
def unit_init(key, cfg, u: Unit):
    if u.kind in ("attn", "cross"):
        return attention.init(key, cfg)
    if u.kind == "mlp":
        return mlp.init(key, cfg)
    if u.kind == "moe":
        return moe.init(key, cfg)
    if u.kind == "mlstm":
        return recurrent.mlstm_init(key, cfg)
    if u.kind == "slstm":
        return recurrent.slstm_init(key, cfg)
    if u.kind == "rglru":
        return recurrent.rglru_init(key, cfg)
    raise ValueError(u.kind)


def unit_cache_init(cfg, u: Unit, batch, max_len, dtype):
    if u.kind == "attn":
        size = min(max_len, u.window * 2) if u.window else max_len
        return attention.init_cache(cfg, batch, size, dtype)
    if u.kind == "cross":
        K, hd = cfg.n_kv_heads, cfg.hd
        S = cfg.encoder_frames
        return (jnp.zeros((batch, S, K, hd), dtype), jnp.zeros((batch, S, K, hd), dtype))
    if u.kind == "mlstm":
        return recurrent.mlstm_cache_init(cfg, batch, dtype)
    if u.kind == "slstm":
        return recurrent.slstm_cache_init(cfg, batch, dtype)
    if u.kind == "rglru":
        return recurrent.rglru_cache_init(cfg, batch, dtype)
    return ()  # mlp/moe: stateless


ZERO_AUX = ()


def unit_apply(u: Unit, p, x, cfg, mode: str, cache, ctx: dict[str, Any]):
    """Returns (x, new_cache, aux_losses tuple)."""
    if isinstance(cache, tuple) and len(cache) == 0:
        cache = None  # cache-less (train) scan placeholder
    aux = jnp.zeros((), jnp.float32)
    if u.kind == "attn":
        if mode == "train":
            x = attention.apply_full(p, x, cfg, window=u.window, is_causal=u.causal)
            return x, (), aux
        if mode == "prefill":
            x, cache = attention.apply_prefill(p, x, cfg, cache, window=u.window)
            return x, cache, aux
        x, cache = attention.apply_decode(p, x, cfg, cache, window=u.window)
        return x, cache, aux
    if u.kind == "cross":
        if mode in ("train", "prefill"):
            kv = attention.encode_kv(p, ctx["enc_out"], cfg)
            x = attention.apply_full(p, x, cfg, is_causal=False, kv_override=kv)
            new_cache = kv if mode == "prefill" else cache
            return x, new_cache, aux
        x = attention.apply_full(p, x, cfg, is_causal=False, kv_override=cache)
        return x, cache, aux
    if u.kind == "mlp":
        return mlp.apply(p, x, cfg), (), aux
    if u.kind == "moe":
        x, a = moe.apply(p, x, cfg)
        return x, (), a["lb_loss"] + a["z_loss"]
    if u.kind == "mlstm":
        x, cache = recurrent.mlstm_apply(p, x, cfg, cache, decode=(mode == "decode"))
        return x, (() if mode == "train" else cache), aux
    if u.kind == "slstm":
        x, cache = recurrent.slstm_apply(p, x, cfg, cache, decode=(mode == "decode"))
        return x, (() if mode == "train" else cache), aux
    if u.kind == "rglru":
        x, cache = recurrent.rglru_apply(p, x, cfg, cache, decode=(mode == "decode"))
        return x, (() if mode == "train" else cache), aux
    raise ValueError(u.kind)


# ------------------------------------------------------------------ stacks
def init_stack(key, cfg, units: tuple[Unit, ...], n: int):
    """Stacked params: tuple over units, each leaf [n, ...].  Initialized
    via vmap over per-layer keys (single trace regardless of depth)."""
    if n == 0 or not units:
        return tuple(() for _ in units)
    nk = len(units)

    def one(layer_key):
        ks = jax.random.split(layer_key, nk)
        return tuple(unit_init(ks[j], cfg, u) for j, u in enumerate(units))

    return jax.vmap(one)(jax.random.split(key, n))


def stack_cache_init(cfg, units, n, batch, max_len, dtype):
    one = tuple(unit_cache_init(cfg, u, batch, max_len, dtype) for u in units)
    if n == 0:
        return one
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n, *x.shape)).copy(), one)


def _superblock_body(units, cfg, mode, ctx):
    def body(x, per_layer):
        params, cache = per_layer
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for j, u in enumerate(units):
            c = cache[j] if cache is not None else None
            x, nc, a = unit_apply(u, params[j], x, cfg, mode, c, ctx)
            new_caches.append(nc)
            aux = aux + a
        return x, tuple(new_caches), aux

    return body


def apply_stack(params, x, cfg, units, n, mode: str, cache=None, ctx=None):
    """Scan the superblock n times.  Returns (x, new_cache, aux_sum)."""
    ctx = ctx or {}
    if n == 0 or not units:
        return x, cache, jnp.zeros((), jnp.float32)
    body = _superblock_body(units, cfg, mode, ctx)

    def scan_fn(x, xs):
        p, c = xs
        if cfg.remat == "full" and mode == "train":
            x, nc, aux = jax.checkpoint(body)(x, (p, c))
        elif cfg.remat == "dots" and mode == "train":
            pol = jax.checkpoint_policies.checkpoint_dots
            x, nc, aux = jax.checkpoint(body, policy=pol)(x, (p, c))
        else:
            x, nc, aux = body(x, (p, c))
        return x, (nc, aux)

    if cache is None:
        cache = tuple(() for _ in units)  # empty pytree: no cache leaves
    x, (new_cache, auxs) = jax.lax.scan(scan_fn, x, (params, cache))
    return x, new_cache, jnp.sum(auxs)


def apply_units_unstacked(params, x, cfg, units, mode, cache=None, ctx=None):
    """Prelude/trailing blocks (not scanned)."""
    ctx = ctx or {}
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for j, u in enumerate(units):
        c = cache[j] if cache is not None else None
        x, nc, a = unit_apply(u, params[j], x, cfg, mode, c, ctx)
        new_caches.append(nc)
        aux = aux + a
    return x, tuple(new_caches), aux
