"""Fluid flow-level datacenter network simulator (the paper's NS3 stand-in).

Two engines share one physics (netsim/dataplane.py): ``engine`` is the
dense O(F)-per-step oracle, ``compact`` the active-window O(W) production
path, and ``sweep`` batches traces over it under a single vmapped compile
(DESIGN.md §9).
"""
from repro.netsim import (
    compact, dataplane, dcqcn, engine, metrics, sweep, topology, workloads,
)

__all__ = [
    "compact", "dataplane", "dcqcn", "engine", "metrics", "sweep",
    "topology", "workloads",
]
