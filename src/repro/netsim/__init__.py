"""Fluid flow-level datacenter network simulator (the paper's NS3 stand-in)."""
from repro.netsim import dcqcn, engine, metrics, topology, workloads

__all__ = ["dcqcn", "engine", "metrics", "topology", "workloads"]
