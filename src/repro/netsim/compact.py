"""Active-window compacted fluid simulator (DESIGN.md §9/§10).

The dense engine (netsim/engine.py) does O(F) work per ``dt`` step over all
flows in the trace — but at any instant only a small working set is in
flight (most flows already finished or not yet arrived).  This engine sorts
flows by arrival and carries a compact ``[W, N]`` working set of *slots*:

  * admit   — each step, flows whose arrival time has passed are gathered
    into free slots in arrival order (``searchsorted`` on the sorted arrival
    vector gives the arrived count; free slots are ranked by cumsum).
    Admission also snapshots everything the per-step physics needs about
    the flow into the slot-indexed ``SlotCache`` (NIC/fabric link ids, leaf
    ids, DCQCN salts, host ids) — placed sub-flows never move, so none of
    it has to be re-derived from the trace or topology per step.
  * run     — the per-step physics (path choice, DCQCN, hop cascade, ECN)
    is byte-identical to the dense engine but over W slots, via the shared
    netsim/dataplane.py pipeline (NIC-tiered cascade).
  * finish  — completed slots scatter their finish time into a global
    ``[F]`` vector (scatter-min, drop-mode for empty slots) and free up.

W is a precomputed max-concurrency bound from the trace
(``max_concurrency_bound``), padded up.  If the bound is ever exceeded the
engine does not lose flows: arrivals queue at the NIC and admit as slots
free (``spill_steps`` in the result counts the steps where that happened,
so callers can verify the bound held — it should be 0 for results that
must match the dense oracle bit-for-bit-ish).

The step loop runs as ``cfg.chunk_steps``-long ``lax.scan`` chunks inside
an early-exit ``while_loop`` (once every flow has admitted and finished
and the queues have drained, the remaining steps are exact no-ops), and
``cfg.uplink_sample_every`` folds the imbalance window-averaging into the
scan so sweeps stop materializing the full ``[T, L, S]`` uplink trace.

The dense engine stays available as the correctness oracle
(``benchmarks/common.run_sim(dense=True)``); equivalence is asserted in
tests/test_netsim_compact.py and recorded per-sweep in BENCH_netsim.json.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, congestion_table as ctab, hashing, routing
from repro.netsim import dataplane, dcqcn as dcqcn_mod
from repro.netsim.engine import (
    DONE_EPS_BYTES, SimConfig, StepOutputs, flow_constants, line_rate_of,
)
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace


class SlotCache(NamedTuple):
    """Admit-time route cache: per-slot constants snapshotted when a flow
    lands in its slot, so the per-step physics never gathers from the
    ``[F]`` trace arrays or re-derives link ids from the topology.  Stale
    entries of freed slots are harmless — their offered rate is 0, so they
    contribute exact +0.0 to every segment-sum they touch."""

    tx: jax.Array  # i32[W] host_tx link id
    rx: jax.Array  # i32[W] host_rx link id
    fab: jax.Array  # i32[W, N, Hf] fabric link ids (schemes with pinned paths)
    sleaf: jax.Array  # i32[W]
    dleaf: jax.Array  # i32[W]
    salt: jax.Array  # u32[W, N] DCQCN mark-draw salt
    fid: jax.Array  # u32[W] flow id (flowlet reroute rng)
    src: jax.Array  # i32[W] source host (DRILL spray)
    dst: jax.Array  # i32[W]
    spray: jax.Array  # i32[W] straddled-path count (flowcell reorder cost)


class CompactState(NamedTuple):
    slot_fid: jax.Array  # i32[W] sorted-flow index; F_pad = empty sentinel
    remaining: jax.Array  # f32[W, N]
    path: jax.Array  # i32[W, N]
    sub_done: jax.Array  # bool[W, N]
    cc: dcqcn_mod.DCQCNState  # [W, N]
    cqe_bitmap: jax.Array  # u32[W]
    admitted: jax.Array  # i32 — flows admitted so far (prefix of sorted order)
    finish: jax.Array  # f32[F_pad] global (+inf until CQE)
    table: ctab.CongestionTable  # [n_leaf, n_paths]
    queue: jax.Array  # f32[n_links + 1]
    cnp_pkts: jax.Array  # f32 scalar
    spill_steps: jax.Array  # i32 — steps where an arrived flow found no slot
    step: jax.Array  # i32
    ff_steps: jax.Array  # i32 — steps advanced by quiescence fast-forward
    cache: SlotCache


class CompactResult(NamedTuple):
    """Duck-types the SimState fields the metrics layer reads."""

    finish: np.ndarray  # f32[F] original trace order
    cnp_pkts: np.ndarray  # f32 scalar
    spill_steps: int
    window_slots: int = 0  # W the (final) run used
    ff_steps: int = 0  # dt steps covered by closed-form fast-forward
    ring: object = None  # obs.recorder.RingState when recording was on


def max_concurrency_bound(
    sizes: np.ndarray,
    arrivals: np.ndarray,
    valid: np.ndarray,
    line_rate: float,
    *,
    slack_slowdown: float = 12.0,
    slack_s: float = 150e-6,
    safety: float = 1.2,
) -> int:
    """Estimated bound on concurrently-active flows: assume every flow lives
    ``slack_slowdown`` x its line-rate serialization plus ``slack_s`` of
    fixed queueing/RTT headroom, then take the max interval overlap.

    This is a heuristic, not a guarantee — the engine reports
    ``spill_steps > 0`` when it was exceeded, and netsim/sweep.py reruns
    with a doubled window in that case (the spilled run stays physically
    sensible — admission is just delayed — but only a spill-free run matches
    the dense oracle exactly)."""
    a = np.asarray(arrivals, np.float64)[np.asarray(valid, bool)]
    s = np.asarray(sizes, np.float64)[np.asarray(valid, bool)]
    if a.size == 0:
        return 64
    order = np.argsort(a, kind="stable")
    a = a[order]
    end = np.sort(a + s[order] * 8.0 / line_rate * slack_slowdown + slack_s)
    # flows started minus flows (optimistically) ended at each arrival
    started = np.arange(1, a.size + 1)
    ended = np.searchsorted(end, a, side="left")
    conc = int((started - ended).max())
    return int(conc * safety) + 64


def max_admits_per_step(arrivals: np.ndarray, valid: np.ndarray, dt: float) -> int:
    """Exact peak number of arrivals in any one ``dt`` step (the admission
    lane width A: per-step path selection runs on [A], not [W])."""
    a = np.asarray(arrivals, np.float64)[np.asarray(valid, bool)]
    if a.size == 0:
        return 1
    steps = np.ceil(a / dt).astype(np.int64)
    return int(np.bincount(steps - steps.min()).max())


def plan_single_window(topo: Topology, cfg: SimConfig, arrays: tuple,
                       F_pad: int) -> tuple[int, int]:
    """(W, A) for a single sorted trace: the concurrency-bound window
    (128-bucketed, floored at min(128, F_pad)) and the exact-peak admission
    lane (32-bucketed).  Shared by ``simulate_compact`` and the --profile
    harness so profiling always times the production shapes."""
    line_rate = float(np.asarray(line_rate_of(topo)))
    bound = max_concurrency_bound(arrays[0], arrays[1], arrays[5], line_rate)
    W = int(min(((bound + 127) // 128) * 128, F_pad))
    W = max(W, min(128, F_pad))
    A = min(((max_admits_per_step(arrays[1], arrays[5], cfg.dt) + 31) // 32) * 32,
            F_pad)
    return W, A


def init_compact_state(
    topo: Topology, cfg: SimConfig, W: int, F_pad: int,
    finish0: jax.Array | None = None, capacity: jax.Array | None = None,
) -> CompactState:
    """Fresh all-slots-empty state.  ``finish0`` (f32[F_pad] of +inf) may be
    built OUTSIDE the jitted run and donated — it is the one state buffer
    large enough to matter, and it aliases the finish output exactly.
    ``capacity`` optionally overrides ``topo.capacity`` as a TRACED operand
    (co-sim fault schedules; see ``run_core``) — either f32[n_links + 1] or
    a wall-clock schedule f32[K, n_links + 1] (row 0 seeds the DCQCN line
    rate)."""
    N = cfg.n_sub
    if capacity is None:
        line_rate = line_rate_of(topo)
    else:
        cap0 = capacity[0] if capacity.ndim == 2 else capacity
        line_rate = cap0[topo.n_links - 2 * topo.n_hosts]
    if finish0 is None:
        finish0 = jnp.full((F_pad,), jnp.inf, jnp.float32)
    hf = topo.n_fabric_hops
    cache = SlotCache(
        tx=jnp.zeros((W,), jnp.int32),
        rx=jnp.zeros((W,), jnp.int32),
        fab=jnp.zeros((W, N, hf), jnp.int32),
        sleaf=jnp.zeros((W,), jnp.int32),
        dleaf=jnp.zeros((W,), jnp.int32),
        salt=jnp.zeros((W, N), jnp.uint32),
        fid=jnp.zeros((W,), jnp.uint32),
        src=jnp.zeros((W,), jnp.int32),
        dst=jnp.zeros((W,), jnp.int32),
        spray=jnp.ones((W,), jnp.int32),
    )
    return CompactState(
        slot_fid=jnp.full((W,), F_pad, jnp.int32),
        remaining=jnp.zeros((W, N), jnp.float32),
        path=jnp.full((W, N), -1, jnp.int32),
        sub_done=jnp.zeros((W, N), bool),
        cc=dcqcn_mod.init_state((W, N), line_rate),
        cqe_bitmap=jnp.zeros((W,), jnp.uint32),
        admitted=jnp.zeros((), jnp.int32),
        finish=finish0,
        table=ctab.CongestionTable.create(topo.n_leaf, topo.n_paths),
        queue=jnp.zeros((topo.n_links + 1,), jnp.float32),
        cnp_pkts=jnp.zeros((), jnp.float32),
        spill_steps=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        ff_steps=jnp.zeros((), jnp.int32),
        cache=cache,
    )


def build_compact_sim(topo: Topology, cfg: SimConfig, trace_arrays, W: int, F_pad: int,
                      A: int = 256, gate_admission: bool = False,
                      capacity: jax.Array | None = None,
                      loss: jax.Array | None = None,
                      cap_seg_steps: int = 0,
                      reorder: jax.Array | None = None):
    """trace_arrays = (sizes, arrivals, src, dst, fid, valid[, spray]),
    SORTED by arrival (invalid flows last, arrival=+inf), padded to F_pad;
    the optional 7th ``spray`` column (i32, defaulted to ones) is the
    straddled-path count flowcell splitting stamps on each flow.
    ``A`` is the admission lane width: at most A flows admit per step, and
    admission-time work (path selection, route-cache fills, slot resets)
    runs on [A]-shaped rank arrays rather than the full [W] window.
    ``gate_admission`` wraps the admission block in a ``lax.cond`` on
    "every flow already admitted" — paper traces stop arriving at 1/4 of
    the horizon, so un-vmapped runs then skip the whole O(W) block.  Only
    set it for programs that will NOT be vmapped: vmap lowers cond to
    both-branches-plus-select, which pays instead of saves.
    ``capacity`` (f32[n_links + 1], sentinel slot included) overrides
    ``topo.capacity`` as a TRACED operand: co-sim fault schedules mutate
    link capacities every planning epoch, and a traced capacity lets all
    epochs share ONE compiled program instead of recompiling per fault
    state.  A 2-D schedule f32[K, n_links + 1] extends that to WALL-CLOCK
    granularity (faults.FaultCampaign): the step loop reads row
    ``min(step // cap_seg_steps, K - 1)``, so link flaps / PFC pauses land
    mid-horizon while K and ``cap_seg_steps`` stay static — shapes fixed,
    still one compiled program for the whole campaign.  ``None`` keeps the
    topology's capacity baked in as a constant (bit-identical to the
    pre-traced-capacity programs).
    ``loss`` (f32[n_links + 1], traced) is the per-link packet-loss vector
    (faults.LossyLink): delivered throughput deflates by the go-back-N
    goodput factor along each sub-flow's hops while offered load stays at
    the DCQCN rate — retransmissions ride the wire (paper Table 1).
    ``reorder`` (f32 scalar, traced) is the flowcell reordering budget in
    packets: delivered throughput divides by
    ``dataplane.reorder_gbn_factor`` wherever the spray column says a
    flow's parent chunk straddles more than one path.  ``None`` (Python
    gate, same convention as ``loss``) traces the exact pre-flowcell
    program — the degenerate pin AND the "cost-free reordering" bench arm.
    Returns (init_state, step_fn, phases) — ``phases`` maps the profile
    phase names (admit / cascade / dcqcn / finish) to the closures
    ``step_fn`` composes, for benchmarks/run.py --profile."""
    arrs = tuple(jnp.asarray(a) for a in trace_arrays)
    if len(arrs) == 6:  # legacy 6-tuple: no flowcell splitting anywhere
        arrs = arrs + (jnp.ones_like(arrs[2]),)
    sizes, arrivals, src, dst, fid, valid, spray_f = arrs
    N = cfg.n_sub
    P = topo.n_paths
    nl = topo.n_links

    fc = flow_constants(topo, cfg, sizes, src, dst, fid)
    if capacity is None:
        cap0 = topo.capacity

        def cap_of(step):
            return topo.capacity
    else:
        cap_arr = jnp.asarray(capacity)
        if cap_arr.ndim == 2:
            cap0 = cap_arr[0]
            seg = max(int(cap_seg_steps), 1)
            Kseg = cap_arr.shape[0]

            def cap_of(step):
                return cap_arr[jnp.minimum(step // seg, Kseg - 1)]
        else:
            cap0 = cap_arr

            def cap_of(step):
                return cap_arr
    loss_vec = None if loss is None else jnp.asarray(loss)
    line_rate = cap0[nl - 2 * topo.n_hosts]  # host_tx[0] bw
    qmask = dataplane.queue_mask_for(topo)
    dparams = cfg.dcqcn

    if cfg.scheme in ("conga", "drill", "flowlet_timeout"):
        assert topo.kind == "leaf_spine", f"{cfg.scheme} is 2-tier only (paper §IV.B)"
    if loss_vec is not None:
        assert cfg.scheme != "drill", \
            "lossy links + DRILL spray unsupported (spray has no pinned hops)"
    if reorder is not None:
        assert topo.kind == "leaf_spine", "reorder cost model is 2-tier only"
        assert cfg.scheme != "drill", \
            "DRILL carries its own gbn factor (drill_gbn_factor)"

    def init_state() -> CompactState:
        return init_compact_state(topo, cfg, W, F_pad, capacity=capacity)

    full_cqe = (jnp.uint32(1) << jnp.uint32(N)) - jnp.uint32(1)
    # schemes whose sub-flow paths are pinned at admission carry their
    # fabric link ids in the SlotCache; flowlet schemes may reroute any
    # slot any step, so their (N=1) fabric row is rebuilt from the cached
    # leaf ids — pure arithmetic, no [F]-sized gathers
    cached_fab = cfg.scheme in ("seqbalance", "ecmp")

    n_valid_total = jnp.sum(valid.astype(jnp.int32))

    def _admission(state: CompactState):
        """The gated part of admit_phase: gather-on-admit, slot resets,
        route-cache fill, and NEW-flow path placement.  Runs under a
        ``lax.cond`` — once every flow has admitted (arrivals stop early in
        paper traces) this whole O(W) block is skipped for the rest of the
        run (a real branch in un-vmapped runs; both-branches-plus-select
        under vmap, which costs one cheap select per state leaf)."""
        t = state.step.astype(jnp.float32) * cfg.dt
        occ_prev = state.slot_fid < F_pad
        n_arr = jnp.searchsorted(arrivals, t, side="right").astype(jnp.int32)
        backlog = n_arr - state.admitted
        free = ~occ_prev
        free_rank = jnp.cumsum(free) - 1  # i32[W]
        m = jnp.minimum(jnp.minimum(backlog, free.sum()), A)
        newly = free & (free_rank < m)
        slot_fid = jnp.where(newly, state.admitted + free_rank, state.slot_fid)

        # admission lane: rank k in [0, A) takes flow admitted+k and lands
        # in the k-th free slot.  All admission-time work happens on these
        # [A]-shaped arrays and scatters into the [W] window (mode="drop"
        # discards ranks beyond m via the W sentinel).
        ranks = jnp.arange(A, dtype=jnp.int32)
        rank_fid = jnp.minimum(state.admitted + ranks, F_pad - 1)  # [A]
        slot_of_rank = jnp.full((A,), W, jnp.int32).at[
            jnp.where(newly, free_rank, A)
        ].set(jnp.arange(W, dtype=jnp.int32), mode="drop")

        # route cache: one [F]-gather per constant at admission, never again
        src_a, dst_a = src[rank_fid], dst[rank_fid]
        sleaf_a, dleaf_a = fc.src_leaf[rank_fid], fc.dst_leaf[rank_fid]
        tx_a, rx_a = topo.nic_links(src_a, dst_a)
        ca = state.cache
        cache = ca._replace(
            tx=ca.tx.at[slot_of_rank].set(tx_a, mode="drop"),
            rx=ca.rx.at[slot_of_rank].set(rx_a, mode="drop"),
            sleaf=ca.sleaf.at[slot_of_rank].set(sleaf_a, mode="drop"),
            dleaf=ca.dleaf.at[slot_of_rank].set(dleaf_a, mode="drop"),
            salt=ca.salt.at[slot_of_rank].set(fc.sub_salt[rank_fid], mode="drop"),
            fid=ca.fid.at[slot_of_rank].set(fid[rank_fid], mode="drop"),
            src=ca.src.at[slot_of_rank].set(src_a, mode="drop"),
            dst=ca.dst.at[slot_of_rank].set(dst_a, mode="drop"),
            spray=ca.spray.at[slot_of_rank].set(spray_f[rank_fid], mode="drop"),
        )

        # reset admitted slots (rank -> slot scatters)
        remaining = state.remaining.at[slot_of_rank].set(
            fc.sub_sizes[rank_fid], mode="drop")
        sub_done = state.sub_done.at[slot_of_rank].set(False, mode="drop")
        cqe_bitmap = state.cqe_bitmap.at[slot_of_rank].set(
            jnp.uint32(0), mode="drop")
        cc = jax.tree.map(
            lambda old, init: old.at[slot_of_rank].set(init, mode="drop"),
            state.cc, dcqcn_mod.init_state((A, N), line_rate),
        )

        # ---------------- NEW-flow path placement (dense-engine logic) --
        # new flows route on the [A] admission lane; the flowlet schemes'
        # per-step reroute of EXISTING slots lives in admit_phase below
        # (it must run even when this block is skipped)
        path = state.path
        if cfg.scheme == "seqbalance":
            inact = ctab.inactive_matrix(state.table, t)  # [L, P]
            stale = inact.sum(-1, keepdims=True) > (P // 2)
            inact = jnp.where(stale, False, inact)
            rows = inact[sleaf_a][:, None, :]  # [A, 1, P]
            rows = jnp.broadcast_to(rows, (A, N, P))
            s5_a = tuple(a[rank_fid] for a in fc.s5)  # each [A, N]
            p_new = routing.select_paths(*s5_a, rows, P)  # [A, N]
            path = path.at[slot_of_rank].set(p_new, mode="drop")
        elif cfg.scheme in ("ecmp", "letflow", "conga", "flowlet_timeout"):
            f5_a = tuple(a[rank_fid] for a in fc.f5)  # each [A]
            p_new = routing.ecmp_paths(*f5_a, P)[:, None]  # [A, 1]
            path = path.at[slot_of_rank].set(p_new, mode="drop")
        else:  # drill: nominal path 0; real split via weights below
            path = path.at[slot_of_rank].set(0, mode="drop")

        if cached_fab:
            fab_a = topo.fabric_links(
                sleaf_a[:, None], dleaf_a[:, None], p_new)  # [A, N, Hf]
            cache = cache._replace(
                fab=cache.fab.at[slot_of_rank].set(fab_a, mode="drop"))

        return state._replace(
            slot_fid=slot_fid, remaining=remaining, path=path,
            sub_done=sub_done, cc=cc, cqe_bitmap=cqe_bitmap,
            admitted=state.admitted + m,
            spill_steps=state.spill_steps + (backlog > m).astype(jnp.int32),
            cache=cache,
        )

    def admit_phase(state: CompactState):
        """Admission (optionally gated: skipped once every flow has
        admitted) plus the flowlet schemes' per-step reroute.  Step time
        is derived from ``state.step`` inside ``_admission`` (the lax.cond
        branch takes the state as its only operand)."""
        occ_prev = state.slot_fid < F_pad
        if gate_admission:
            st = jax.lax.cond(
                state.admitted < n_valid_total, _admission, lambda s: s, state)
        else:
            st = _admission(state)
        if cfg.scheme in ("letflow", "conga", "flowlet_timeout"):
            # reroute EXISTING slots at flowlet gaps; newly admitted slots
            # keep their ECMP placement (occ_prev is pre-admission)
            rng = hashing.fmix32(
                st.cache.fid ^ st.step.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
            )
            gap = baselines.flowlet_gap_occurs(
                st.cc.rc[:, 0], dparams.mtu_bytes, cfg.flowlet_timeout
            )
            if cfg.scheme == "letflow":
                p_re = baselines.letflow_paths(st.path[:, 0], gap, rng, P)
            elif cfg.scheme == "flowlet_timeout":
                # WCMP flowlet re-draw weighted by the CURRENT per-leaf
                # uplink capacities (traced schedules included) — the
                # asymmetric-topology flowlet controller: fat uplinks
                # absorb proportionally more flowlets.
                capv_a = cap_of(st.step)
                cap_up = capv_a[: topo.n_leaf * P].reshape(topo.n_leaf, P)
                w_leaf = baselines.wcmp_weights(cap_up)  # [L, P]
                p_re = baselines.flowlet_wcmp_paths(
                    st.path[:, 0], gap, rng, w_leaf[st.cache.sleaf])
            else:
                pq = dataplane.path_queue_2tier(
                    topo, st.queue, st.cache.sleaf, st.cache.dleaf)
                p_re = baselines.conga_paths(st.path[:, 0], gap, pq)
            path = jnp.where(occ_prev, p_re, st.path[:, 0])[:, None]  # [W, 1]
            st = st._replace(path=path)
        return st

    def cascade_phase(state: CompactState):
        """Offered rates -> NIC-tiered hop cascade -> queue/ECN marks.
        Returns (arrival, new_queue, thr, p_sub, p_sub_fabric, rc, active)."""
        occupied = state.slot_fid < F_pad
        active = occupied[:, None] & ~state.sub_done
        rc = jnp.where(
            active, jnp.minimum(state.cc.rc, state.remaining * 8.0 / cfg.dt), 0.0
        )  # [W, N]
        ca = state.cache
        capv = cap_of(state.step)  # wall-clock schedule row (or the vector)
        if cfg.scheme == "drill":
            arrival, thr, w_spray, pq = dataplane.drill_spray(
                topo, state.queue, rc[:, 0], ca.src, ca.dst, ca.sleaf, ca.dleaf,
                active[:, 0:1], cfg.drill_q0, capacity=capv,
            )
            new_queue, p_mark = dataplane.integrate_queue(
                state.queue, arrival, capv, qmask, dparams,
                dt=cfg.dt, qmax_bytes=cfg.qmax_bytes, n_links=nl,
            )
            p_sub, p_sub_fabric = dataplane.drill_mark_probs(
                topo, p_mark, w_spray, ca.sleaf, ca.dleaf, ca.dst
            )
            thr = thr * dataplane.drill_gbn_factor(
                topo, pq, w_spray, rc[:, 0], mtu_bytes=dparams.mtu_bytes,
                jitter_mtus=cfg.drill_jitter_mtus, window_pkts=cfg.gbn_window_pkts,
                capacity=capv,
            )
            thr = thr[:, None]  # [W, 1]
        else:
            if cached_fab:
                fab = ca.fab  # admit-time snapshot: paths never move
            else:  # flowlet reroute: rebuild from cached leaf ids (no gathers)
                fab = topo.fabric_links(
                    ca.sleaf, ca.dleaf, state.path[:, 0])[:, None, :]
            arrival, new_queue, p_mark, thr = dataplane.cascade_nic(
                fab, ca.tx, ca.rx, rc, state.queue, capv, qmask,
                n_links=nl, kmin=dparams.kmin_bytes, kmax=dparams.kmax_bytes,
                pmax=dparams.pmax, dt=cfg.dt, qmax_bytes=cfg.qmax_bytes,
                backend=cfg.dataplane,
            )
            p_sub, p_sub_fabric = dataplane.subflow_mark_probs_nic(
                fab, ca.tx, ca.rx, p_mark, nl)
            if loss_vec is not None:
                # GBN amplification on lossy links: goodput deflates, the
                # offered rate (already in the cascade above) does not
                thr = thr * dataplane.lossy_gbn_factor(
                    fab, ca.tx, ca.rx, loss_vec, n_links=nl,
                    window_pkts=cfg.gbn_window_pkts,
                )
            if reorder is not None:
                # flowcell reordering cost: every delivered byte of a
                # path-straddling chunk costs 1 + p_ooo*W/2 wire bytes
                # (go-back-N rewinds); offered load stays at the DCQCN
                # rate — the retransmitted bytes ride the wire, exactly
                # the lossy_gbn_factor convention
                pq = dataplane.path_queue_2tier(
                    topo, state.queue, ca.sleaf, ca.dleaf)
                thr = thr / dataplane.reorder_gbn_factor(
                    topo, pq, ca.spray, rc[:, 0], reorder,
                    mtu_bytes=dparams.mtu_bytes,
                    jitter_mtus=cfg.drill_jitter_mtus,
                    window_pkts=cfg.gbn_window_pkts, capacity=capv,
                )[:, None]
        return arrival, new_queue, thr, p_sub, p_sub_fabric, rc, active

    def dcqcn_phase(state: CompactState, p_sub, active):
        flow_salt = state.cache.salt if cfg.scheme == "seqbalance" \
            else state.cache.salt[:, :1]
        flow_salt = jnp.broadcast_to(flow_salt, (W, N))
        cc, _ = dcqcn_mod.step(
            state.cc, p_sub, active, cfg.dt, line_rate, dparams, state.step,
            flow_salt,
        )
        return cc

    def finish_phase(state: CompactState, t, thr, active, rc, p_sub_fabric):
        """Transfer progress, bitmap CQE, scatter-on-finish, Congestion
        Packet bookkeeping.  Returns (remaining, sub_done, cqe_bitmap,
        slot_fid, finish, table, exp_cong_pkts)."""
        occupied = state.slot_fid < F_pad
        delivered = thr * cfg.dt / 8.0  # bytes
        new_remaining = jnp.maximum(
            state.remaining - jnp.where(active, delivered, 0.0), 0.0)
        sub_done = occupied[:, None] & (new_remaining <= DONE_EPS_BYTES)
        bits = (sub_done.astype(jnp.uint32) << jnp.arange(N, dtype=jnp.uint32)).sum(
            axis=-1, dtype=jnp.uint32
        )
        cqe_bitmap = state.cqe_bitmap | bits
        all_done = ((cqe_bitmap & full_cqe) == full_cqe) & occupied
        # scatter-on-finish: empty slots carry the F_pad sentinel -> dropped
        finish = state.finish.at[state.slot_fid].min(
            jnp.where(all_done, t + cfg.dt, jnp.inf), mode="drop"
        )

        table = state.table
        pkts = jnp.where(active, rc * cfg.dt / (8.0 * dparams.mtu_bytes), 0.0)
        exp_cong_pkts = jnp.sum(pkts * p_sub_fabric)
        if cfg.scheme == "seqbalance":
            intensity = jnp.zeros((topo.n_leaf, P), jnp.float32)
            idx_leaf = jnp.broadcast_to(
                state.cache.sleaf[:, None], (W, N)).reshape(-1)
            idx_path = jnp.clip(state.path, 0, P - 1).reshape(-1)
            intensity = intensity.at[idx_leaf, idx_path].add(
                (pkts * p_sub_fabric).reshape(-1)
            )
            dense_mask = intensity >= cfg.cong_threshold_pkts
            table = ctab.mark_congested_dense(table, dense_mask, t, cfg.phi)
        slot_fid = jnp.where(all_done, F_pad, state.slot_fid)  # free slots
        return (new_remaining, sub_done, cqe_bitmap, slot_fid, finish, table,
                exp_cong_pkts)

    def step_fn(state: CompactState, _=None):
        t = state.step.astype(jnp.float32) * cfg.dt
        st = admit_phase(state)
        arrival, new_queue, thr, p_sub, p_sub_fabric, rc, active = \
            cascade_phase(st)
        cc = dcqcn_phase(st, p_sub, active)
        (remaining, sub_done, cqe_bitmap, slot_fid, finish, table,
         exp_cong_pkts) = finish_phase(st, t, thr, active, rc, p_sub_fabric)

        new_state = st._replace(
            slot_fid=slot_fid,
            remaining=remaining,
            sub_done=sub_done,
            cc=cc,
            cqe_bitmap=cqe_bitmap,
            finish=finish,
            table=table,
            queue=new_queue,
            cnp_pkts=state.cnp_pkts + exp_cong_pkts,
            step=state.step + 1,
        )
        out = StepOutputs(
            uplink_load=arrival[jnp.asarray(topo.uplink_ids)],
            goodput_total=jnp.sum(jnp.where(active, thr, 0.0)),
            cnp_rate=exp_cong_pkts,
            max_queue=jnp.max(new_queue[:nl]),
        )
        return new_state, out

    # ---------------- event-driven adaptive dt (DESIGN.md §15) ----------
    uplink_ids = jnp.asarray(topo.uplink_ids)
    s_win = cfg.uplink_sample_every

    def quiesce_phase(state: CompactState, span: int):
        """Quiescence predicate for a ``span``-step macro-step starting at
        ``state.step``: True iff every one of those steps is provably
        reproducible in closed form, i.e. (a) no flow arrives inside the
        span (so admission is an exact no-op, spill counter included — a
        spill backlog implies the next unadmitted arrival is already in
        the past, which fails this check), (b) the capacity-schedule row is
        constant across the span, and (c) the fabric is either fully idle
        (stale slots offer exact +0.0; marks may exist but nothing consumes
        them) or in steady state: every active sub-flow pinned at
        ``rc == rt == line rate`` (an exact fixed point of the DCQCN
        recovery branch), no masked queue able to reach the
        ``ff_kmin_frac * kmin`` ECN margin under the constant offered
        load, no sub-flow able to finish within ``span + ff_margin_steps``
        steps (which also keeps the remaining-bytes rc cap non-binding),
        and — for the flowlet schemes — no occupied slot at a flowlet gap
        (so the per-step reroute keeps every path fixed).  DRILL's spray
        weights depend on instantaneous queues, so it only fast-forwards
        idle spans.

        Returns the boolean alone.  The steady-state checks cost one hop
        cascade, so they hide behind a ``lax.cond`` on the O(1) arrival and
        capacity-edge checks: event-dense chunks (every chunk of a loaded
        Poisson trace) pay two scalar compares and nothing else, and only
        plausibly quiescent boundaries pay the ~1/span cascade."""
        t_end = (state.step + span).astype(jnp.float32) * cfg.dt
        nxt = arrivals[jnp.clip(state.admitted, 0, F_pad - 1)]
        p_arr = (state.admitted >= n_valid_total) | (nxt >= t_end)
        if capacity is not None and jnp.asarray(capacity).ndim == 2:
            r0 = jnp.minimum(state.step // seg, Kseg - 1)
            r1 = jnp.minimum((state.step + span - 1) // seg, Kseg - 1)
            p_cap = r0 == r1
        else:
            p_cap = jnp.bool_(True)

        def steady_or_idle(st: CompactState):
            occupied = st.slot_fid < F_pad
            idle = ~jnp.any(occupied)
            if cfg.scheme == "drill" or reorder is not None:
                # spray/reorder throughput depends on instantaneous queues,
                # which drift inside a span — only idle spans fast-forward
                return idle
            arrival, _, _, _, _, rc, active = cascade_phase(st)
            capv = cap_of(st.step)
            delta = (arrival - capv) * (cfg.dt / 8.0)
            q_hi = jnp.maximum(st.queue, st.queue + delta * span) * qmask
            p_q = jnp.all(q_hi[:nl] < cfg.ff_kmin_frac * dparams.kmin_bytes)
            margin = span + max(cfg.ff_margin_steps, 1)
            need = rc * (margin * cfg.dt / 8.0) + DONE_EPS_BYTES
            p_fin = jnp.all(jnp.where(active, st.remaining > need, True))
            p_cc = jnp.all(jnp.where(
                active,
                (st.cc.rc == line_rate) & (st.cc.rt == line_rate),
                True,
            ))
            steady = p_q & p_fin & p_cc
            if cfg.scheme in ("letflow", "conga", "flowlet_timeout"):
                gap = baselines.flowlet_gap_occurs(
                    st.cc.rc[:, 0], dparams.mtu_bytes, cfg.flowlet_timeout)
                steady &= ~jnp.any(gap & occupied)
            return idle | steady

        return jax.lax.cond(
            p_arr & p_cap, steady_or_idle, lambda st: jnp.bool_(False), state)

    def fast_forward_phase(state: CompactState, span: int):
        """Advance ``span`` steps in closed form — valid exactly when
        ``quiesce_phase(state, span)`` holds.  Queues follow the analytic
        clip trajectory, remaining bytes decrement linearly at the frozen
        delivered rate, DCQCN reduces to timer bookkeeping
        (dcqcn.fast_forward), and every discrete structure (slots, CQE
        bitmaps, finish times, congestion table, CNP counter, spill) is
        untouched.  Step outputs are the frozen per-step values broadcast
        over the span; the uplink slab is emitted at sample-window
        granularity directly (a window average of a constant).  Runs its
        own cascade — one extra hop cascade per fast-forwarded macro-step,
        amortised over the ``span`` scanned steps it replaces."""
        arrival, _, thr, _, _, _, active = cascade_phase(state)
        capv = cap_of(state.step)
        q_final, mq_traj = dataplane.queue_fast_forward(
            state.queue, arrival, capv, qmask,
            dt=cfg.dt, n_steps=span, qmax_bytes=cfg.qmax_bytes, n_links=nl,
        )
        delivered = thr * (span * cfg.dt / 8.0)
        remaining = jnp.maximum(
            state.remaining - jnp.where(active, delivered, 0.0), 0.0)
        cc = dcqcn_mod.fast_forward(state.cc, active, span, cfg.dt, dparams)
        new_state = state._replace(
            remaining=remaining, cc=cc, queue=q_final,
            step=state.step + span, ff_steps=state.ff_steps + span,
        )
        up = jnp.broadcast_to(
            arrival[uplink_ids][None],
            (span // s_win,) + np.asarray(topo.uplink_ids).shape)
        outs = StepOutputs(
            uplink_load=up,
            goodput_total=jnp.broadcast_to(
                jnp.sum(jnp.where(active, thr, 0.0)), (span,)),
            cnp_rate=jnp.zeros((span,), jnp.float32),
            max_queue=mq_traj,
        )
        return new_state, outs

    phases = dict(admit=admit_phase, cascade=cascade_phase,
                  dcqcn=dcqcn_phase, finish=finish_phase,
                  quiesce=quiesce_phase, fast_forward=fast_forward_phase)
    return init_state, step_fn, phases


def plan_chunks(cfg: SimConfig, n_steps: int) -> tuple[int, int, int]:
    """(K, n_chunks, tail): scan-chunk length (a multiple of the uplink
    sample window, capped at the horizon), full chunks, and leftover steps.

    Prefers a K that divides the horizon: a nonzero tail needs its own
    lax.cond'd scan, which compiles the step body a SECOND time — a pure
    compile-latency tax that a slightly shorter chunk avoids entirely.
    The search runs from the requested chunk size all the way down to one
    sample window, so the tail only survives when the sample window itself
    does not divide the horizon (then no valid K can)."""
    s = cfg.uplink_sample_every
    K0 = max(1, cfg.chunk_steps // s) * s
    K0 = min(K0, max(n_steps, 1))
    for k in range(K0, 0, -1):
        if k % s == 0 and n_steps % k == 0:
            return k, n_steps // k, 0
    return K0, n_steps // K0, n_steps % K0


def event_grid(cfg: SimConfig, n_steps: int, arrivals=None, valid=None,
               cap_seg_steps: int = 0) -> np.ndarray:
    """Mandatory step boundaries for one sim, host-side: flow-arrival
    steps, fault/capacity segment edges, and uplink sample-window
    boundaries.  The adaptive engine honors this grid by construction —
    macro-steps are whole scan chunks (K a multiple of the sample window,
    via ``plan_chunks``), the quiescence predicate refuses any span
    containing an arrival or a capacity edge, and finishes/ECN crossings
    are excluded dynamically.  Exposed for planning and for the
    ``--profile`` quiescence-occupancy report."""
    edges = [np.array([0, n_steps], np.int64)]
    if arrivals is not None:
        a = np.asarray(arrivals, np.float64)
        if valid is not None:
            a = a[np.asarray(valid, bool)]
        a = a[np.isfinite(a)]
        steps = np.ceil(a / cfg.dt).astype(np.int64)
        edges.append(steps[(steps >= 0) & (steps <= n_steps)])
    if cap_seg_steps and cap_seg_steps > 0:
        edges.append(np.arange(0, n_steps + 1, cap_seg_steps, dtype=np.int64))
    if cfg.uplink_sample_every > 1:
        edges.append(np.arange(0, n_steps + 1, cfg.uplink_sample_every,
                               dtype=np.int64))
    return np.unique(np.concatenate(edges))


def run_core(topo: Topology, cfg: SimConfig, W: int, F_pad: int, A: int,
             n_steps: int, trace_arrays, finish0: jax.Array,
             capacity: jax.Array | None = None,
             loss: jax.Array | None = None,
             cap_seg_steps: int = 0,
             gate_admission: bool = False,
             record=None,
             reorder: jax.Array | None = None):
    """Jit-friendly core: sorted/padded trace arrays + a donatable +inf
    finish buffer in, (finish[F_pad] in sorted order, cnp_pkts, spill_steps,
    ff_steps, per-step outputs) out.  Wrapped and cached by netsim/sweep.py;
    vmap-able over a leading batch axis of (trace_arrays, finish0).
    ``capacity`` (f32[n_links + 1], or a wall-clock schedule
    f32[K, n_links + 1] stepped every ``cap_seg_steps`` — static — steps)
    is the TRACED link-capacity operand for co-sim fault schedules, and
    ``loss`` (f32[n_links + 1], traced) the per-link loss rates driving
    go-back-N goodput amplification — see ``build_compact_sim``; None
    keeps ``topo.capacity`` baked in as a compile-time constant.

    The horizon runs as K-step ``lax.scan`` chunks inside a ``while_loop``
    with EARLY EXIT: once every flow has been admitted and finished and the
    queues have fully drained, the remaining steps of the horizon are exact
    no-ops (zero offered load, zero queues — also in the dense engine), so
    whole chunks are skipped and the preallocated per-step outputs keep
    their zeros.  Typical paper sweeps (arrivals stop at 1/4 of the
    horizon) skip 30-50 % of steps this way.  With
    ``cfg.uplink_sample_every > 1`` the uplink trace is window-averaged
    inside the chunk before it is written out, so only ``[T/s, L, S]`` is
    ever materialized.

    With ``cfg.adaptive`` every chunk boundary additionally evaluates the
    quiescence predicate and a ``lax.cond`` fast-forwards the whole
    macro-step (``cfg.ff_macro_chunks`` chunks) in closed form when it
    holds — the event grid (arrivals, capacity segment edges, sample
    windows; see ``event_grid``) is respected by construction because
    macro-steps are chunk-aligned and the predicate refuses spans
    containing an event.  The cond is a REAL branch exactly on the
    un-vmapped dispatch paths (B=1 / one-sim-per-device), which is where
    the sweep runner lands on CPU; under vmap it lowers to
    both-branches-plus-select and saves nothing.  ``adaptive=False``
    traces the identical step loop as before (bit-identical results).

    ``record`` (an ``obs.recorder.RecordSpec``, static/hashable) appends a
    per-chunk summary row to a fixed-shape ring buffer carried alongside
    the loop state and returns it as a sixth output.  All gating is at
    Python trace time: ``record=None`` traces the identical program as
    before the recorder existed (bit-identical, sha-pinned), and because
    the ring's shapes depend only on the spec, recording costs exactly one
    extra executable per (shape bucket, spec) — never a rebuild across
    epochs (DESIGN.md §16).

    ``reorder`` (f32 scalar, traced) switches on the flowcell
    reordering-cost model — see ``build_compact_sim``; ``None`` traces the
    identical pre-flowcell program (sha-pinned)."""
    _, step_fn, phases = build_compact_sim(topo, cfg, trace_arrays, W, F_pad,
                                           A, gate_admission=gate_admission,
                                           capacity=capacity, loss=loss,
                                           cap_seg_steps=cap_seg_steps,
                                           reorder=reorder)
    init = init_compact_state(topo, cfg, W, F_pad, finish0, capacity=capacity)
    n_valid = jnp.sum(jnp.asarray(trace_arrays[5]).astype(jnp.int32))
    nl = topo.n_links
    uplink_shape = np.asarray(topo.uplink_ids).shape
    s = cfg.uplink_sample_every
    K, n_chunks, tail = plan_chunks(cfg, n_steps)
    n_samples = n_steps // s
    outs0 = StepOutputs(
        uplink_load=jnp.zeros((n_samples,) + uplink_shape, jnp.float32),
        goodput_total=jnp.zeros((n_steps,), jnp.float32),
        cnp_rate=jnp.zeros((n_steps,), jnp.float32),
        max_queue=jnp.zeros((n_steps,), jnp.float32),
    )

    def alive(st):
        return (
            (st.admitted < n_valid)
            | jnp.any(st.slot_fid < F_pad)
            | (jnp.max(st.queue[:nl]) > 0.0)
        )

    def splice(outs, o, k0, length):
        """Write a block's per-step output slab into the preallocated
        horizon outputs at the (chunk-aligned, so sample-window-aligned)
        offset ``k0``."""
        gp = jax.lax.dynamic_update_slice(outs.goodput_total, o.goodput_total, (k0,))
        cn = jax.lax.dynamic_update_slice(outs.cnp_rate, o.cnp_rate, (k0,))
        mq = jax.lax.dynamic_update_slice(outs.max_queue, o.max_queue, (k0,))
        up = outs.uplink_load
        nw = length // s
        if nw:
            slab = o.uplink_load[: nw * s]
            if s > 1:
                slab = slab.reshape((nw, s) + slab.shape[1:]).mean(axis=1)
            up = jax.lax.dynamic_update_slice(
                up, slab, (k0 // s,) + (0,) * len(uplink_shape))
        return StepOutputs(up, gp, cn, mq)

    def run_block(st, outs, length):
        """Scan ``length`` (static) steps; returns the block's raw output
        slab too (only the recorder consumes it — discarded otherwise, at
        Python level, so the traced program is unchanged)."""
        k0 = st.step
        st2, o = jax.lax.scan(step_fn, st, None, length=length)
        return st2, splice(outs, o, k0, length), o

    if record is not None:
        from repro.obs import recorder

        uplink_flat = jnp.asarray(np.asarray(topo.uplink_ids).ravel())
        ring0 = recorder.ring_init(record, int(uplink_flat.size))
        if capacity is None:
            cap_row = jnp.asarray(topo.capacity)[uplink_flat]

            def cap_row_of(step):
                return cap_row
        else:
            cap_arr_r = jnp.asarray(capacity)
            if cap_arr_r.ndim == 2:
                seg_r = max(int(cap_seg_steps), 1)
                kseg_r = cap_arr_r.shape[0]

                def cap_row_of(step):
                    row = cap_arr_r[jnp.minimum(step // seg_r, kseg_r - 1)]
                    return row[uplink_flat]
            else:
                cap_row_r = cap_arr_r[uplink_flat]

                def cap_row_of(step):
                    return cap_row_r

        def rec_chunk(ring, st0, st2, o, length, ff):
            """One ring row from a block's raw slab + boundary state.
            ``o.uplink_load`` is per-step for scanned blocks and per-window
            for fast-forwarded ones — the mean over axis 0 is the chunk
            mean either way (a window average of constants)."""
            occupied = st2.slot_fid < F_pad
            active = occupied[:, None] & ~st2.sub_done
            return recorder.record_chunk(
                record, ring, step0=st0.step, steps=length, ff=ff,
                queue_max=jnp.max(o.max_queue),
                queue_mean=jnp.mean(o.max_queue),
                cnp=jnp.sum(o.cnp_rate), goodput=jnp.mean(o.goodput_total),
                offered=o.uplink_load.mean(axis=0).reshape(-1),
                cap=cap_row_of(st0.step), rc=st2.cc.rc, active=active)

    if cfg.adaptive:
        macro = K * cfg.ff_macro_chunks
        horizon = n_chunks * K
        quiesce, fast_forward = phases["quiesce"], phases["fast_forward"]

        def ff_block(st0, o0):
            st2, o = fast_forward(st0, macro)
            gp = jax.lax.dynamic_update_slice(
                o0.goodput_total, o.goodput_total, (st0.step,))
            cn = jax.lax.dynamic_update_slice(o0.cnp_rate, o.cnp_rate,
                                              (st0.step,))
            mq = jax.lax.dynamic_update_slice(o0.max_queue, o.max_queue,
                                              (st0.step,))
            up = jax.lax.dynamic_update_slice(
                o0.uplink_load, o.uplink_load,
                (st0.step // s,) + (0,) * len(uplink_shape))
            return st2, StepOutputs(up, gp, cn, mq), o

        if record is None:
            def body(c):
                st, outs = c
                quiet = quiesce(st, macro) & ((st.step + macro) <= horizon)

                def do_ff(c2):
                    st2, outs2, _ = ff_block(c2[0], c2[1])
                    return st2, outs2

                def do_run(c2):
                    st2, outs2, _ = run_block(c2[0], c2[1], K)
                    return st2, outs2

                return jax.lax.cond(quiet, do_ff, do_run, c)
        else:
            def body(c):
                st, outs, ring = c
                quiet = quiesce(st, macro) & ((st.step + macro) <= horizon)

                def do_ff(c2):
                    st2, outs2, o = ff_block(c2[0], c2[1])
                    return st2, outs2, rec_chunk(c2[2], c2[0], st2, o,
                                                 macro, 1)

                def do_run(c2):
                    st2, outs2, o = run_block(c2[0], c2[1], K)
                    return st2, outs2, rec_chunk(c2[2], c2[0], st2, o, K, 0)

                return jax.lax.cond(quiet, do_ff, do_run, c)
    else:
        if record is None:
            def body(c):
                st2, outs2, _ = run_block(c[0], c[1], K)
                return st2, outs2
        else:
            def body(c):
                st2, outs2, o = run_block(c[0], c[1], K)
                return st2, outs2, rec_chunk(c[2], c[0], st2, o, K, 0)

    carry = (init, outs0) if record is None else (init, outs0, ring0)
    if n_chunks:
        carry = jax.lax.while_loop(
            lambda c: (c[0].step < n_chunks * K) & alive(c[0]),
            body,
            carry,
        )
    if tail:  # horizon not divisible by K: one short block, same early exit
        if record is None:
            def tail_block(c):
                st2, outs2, _ = run_block(c[0], c[1], tail)
                return st2, outs2
        else:
            def tail_block(c):
                st2, outs2, o = run_block(c[0], c[1], tail)
                return st2, outs2, rec_chunk(c[2], c[0], st2, o, tail, 0)
        carry = jax.lax.cond(alive(carry[0]), tail_block, lambda c: c, carry)
    final, outs = carry[0], carry[1]
    base = (final.finish, final.cnp_pkts, final.spill_steps, final.ff_steps,
            outs)
    return base if record is None else base + (carry[2],)


def sort_trace(trace: Trace) -> tuple[tuple, np.ndarray, int]:
    """Sort a trace by arrival (invalid flows last at +inf).  Returns
    (sorted arrays tuple, inverse permutation, n_flows)."""
    valid = np.asarray(trace.valid, bool)
    arr = np.asarray(trace.arrivals, np.float32).copy()
    arr[~valid] = np.inf
    order = np.argsort(arr, kind="stable")
    inv = np.empty_like(order)
    inv[order] = np.arange(order.size)
    arrays = (
        np.asarray(trace.sizes, np.float32)[order],
        arr[order],
        np.asarray(trace.src, np.int32)[order],
        np.asarray(trace.dst, np.int32)[order],
        np.asarray(trace.flow_id, np.uint32)[order],
        valid[order],
        np.asarray(trace.spray, np.int32)[order],
    )
    return arrays, inv, order.size


def pad_trace_arrays(arrays: tuple, F_pad: int) -> tuple:
    sizes, arr, src, dst, fid, valid, spray = arrays
    pad = F_pad - sizes.shape[0]
    assert pad >= 0, (sizes.shape[0], F_pad)
    if pad == 0:
        return arrays
    return (
        np.concatenate([sizes, np.ones(pad, np.float32)]),
        np.concatenate([arr, np.full(pad, np.inf, np.float32)]),
        np.concatenate([src, np.zeros(pad, np.int32)]),
        np.concatenate([dst, np.zeros(pad, np.int32)]),
        np.concatenate([fid, np.zeros(pad, np.uint32)]),
        np.concatenate([valid, np.zeros(pad, bool)]),
        np.concatenate([spray, np.ones(pad, np.int32)]),
    )


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5), donate_argnums=(7,))
def _run_single(topo, cfg, W, F_pad, A, n_steps, trace_arrays, finish0):
    return run_core(topo, cfg, W, F_pad, A, n_steps, trace_arrays, finish0,
                    gate_admission=True)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5), donate_argnums=(7,))
def _run_single_reorder(topo, cfg, W, F_pad, A, n_steps, trace_arrays,
                        finish0, reorder):
    return run_core(topo, cfg, W, F_pad, A, n_steps, trace_arrays, finish0,
                    gate_admission=True, reorder=reorder)


def simulate_compact(
    topo: Topology, cfg: SimConfig, trace: Trace, *,
    window_slots: int | None = None, reorder=None,
) -> tuple[CompactResult, StepOutputs]:
    """One-shot compact run (single trace; for sweeps use netsim/sweep.py).

    Drop-in for ``engine.simulate`` where only finish times / CNP counts /
    per-step outputs are consumed.  ``reorder`` (float packets or None)
    enables the flowcell reordering cost as a traced budget."""
    arrays, inv, F = sort_trace(trace)
    F_pad = max(F, 1)
    W, A = plan_single_window(topo, cfg, arrays, F_pad)
    if window_slots is not None:  # explicit window: honor it exactly
        W = max(8, min(int(window_slots), F_pad))  # (tests probe spill)
    n_steps = int(round(cfg.duration_s / cfg.dt))
    if reorder is None:
        finish, cnp, spill, ff, outs = _run_single(
            topo, cfg, W, F_pad, A, n_steps,
            tuple(jnp.asarray(a) for a in arrays),
            jnp.full((F_pad,), jnp.inf, jnp.float32),
        )
    else:
        finish, cnp, spill, ff, outs = _run_single_reorder(
            topo, cfg, W, F_pad, A, n_steps,
            tuple(jnp.asarray(a) for a in arrays),
            jnp.full((F_pad,), jnp.inf, jnp.float32), jnp.float32(reorder),
        )
    res = CompactResult(
        finish=np.asarray(finish)[:F][inv],
        cnp_pkts=np.asarray(cnp),
        spill_steps=int(spill),
        window_slots=W,
        ff_steps=int(ff),
    )
    return res, outs
