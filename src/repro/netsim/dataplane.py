"""Fused switch dataplane: offered-load -> queue -> RED/ECN-mark pipeline.

This is the per-step work of every ToR/spine in the fluid simulator
(DESIGN.md §8/§9), extracted from ``engine.step_fn`` so that one module owns
the hop cascade and both engines (dense oracle and active-window compact)
share bit-identical math:

  hop h arrivals are the UPSTREAM-scaled rates (NIC serializes first, then
  fabric), so for h = 0..H-1:
      load_h[l]  = sum of sub-flow rates (scaled by hops < h) entering l
      scale_h[l] = min(1, cap[l] / load_h[l])
      r         <- r * scale_h[lid_h]
  arrival[l]   = sum_h load_h[l]
  queue[l]    <- clip(queue + (arrival - cap) * dt/8, 0, qmax) * queue_mask
  p_mark[l]    = RED ramp on queue (kmin/kmax/pmax)

Both engines route through the NIC-TIERED form (``cascade_nic``): the N
sub-flows of a flow always share their first (host_tx) and last (host_rx)
hop, so those two hops pre-reduce over N and cost O(W) instead of O(W*N);
only the fabric hops stay per sub-flow.  The flat ``cascade`` (identical
physics, no pre-reduction) is kept as the oracle — tiered vs flat agree to
float round-off (summation grouping differs), checked in
tests/test_netsim_compact.py and the hypothesis property suite.

Backends (both layouts)
  * ``xla``    — ``jax.ops.segment_sum`` per hop (the original engine loop;
    also the correctness oracle, mirrored in ``kernels/ref.py``).
  * ``pallas`` — one fused ``kernels/linkload.py::linkload_cascade`` /
    ``linkload_cascade_tiered`` call: the scatter-adds become one-hot
    matmuls on the MXU, the cascade walks hops in the grid, and queue/mark
    fuse into the final grid step.
  * ``pallas_interpret`` — the same kernel interpreted on CPU (tests).
  * ``auto``   — pallas on TPU, xla everywhere else.

DRILL's per-packet spray does not fit the per-path cascade (it splits one
sub-flow over ALL paths by queue-depth weights), so its 2-tier dataplane
lives here too (``drill_spray``) and is shared by both engines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netsim.topology import Topology

_BACKENDS = ("auto", "xla", "pallas", "pallas_interpret")


def resolve_backend(backend: str) -> str:
    assert backend in _BACKENDS, backend
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def cascade(
    links: jax.Array,  # i32[..., H] link ids, -1 = hop absent
    rates: jax.Array,  # f32[...] offered rate per sub-flow (bps)
    queue: jax.Array,  # f32[n_links + 1] current queue bytes (sentinel last)
    capacity: jax.Array,  # f32[n_links + 1] bps (sentinel = 1e30)
    queue_mask: jax.Array,  # f32[n_links + 1] 0 on queueless links (host_tx)
    *,
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    dt: float,
    qmax_bytes: float,
    backend: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (arrival[n_links+1], new_queue[n_links+1], p_mark[n_links+1],
    thr[...]) — thr is the delivered rate after all hop scales."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return _cascade_xla(
            links, rates, queue, capacity, queue_mask,
            n_links=n_links, kmin=kmin, kmax=kmax, pmax=pmax, dt=dt,
            qmax_bytes=qmax_bytes,
        )
    from repro.kernels import linkload as ll

    shape = rates.shape
    hops = links.shape[-1]
    flat_links = links.reshape(-1, hops)
    flat_rates = rates.reshape(-1)
    arrival_l, newq_l, mark_l, thr = ll.linkload_cascade(
        flat_links, flat_rates, queue[:n_links], capacity[:n_links],
        queue_mask[:n_links], n_links=n_links, kmin=kmin, kmax=kmax,
        pmax=pmax, dt=dt, qmax_bytes=qmax_bytes,
        interpret=(backend == "pallas_interpret"),
    )
    zero = jnp.zeros((1,), jnp.float32)
    arrival = jnp.concatenate([arrival_l, zero])
    new_queue = jnp.concatenate([newq_l, zero])
    p_mark = jnp.concatenate([mark_l, zero])
    return arrival, new_queue, p_mark, thr.reshape(shape)


def cascade_nic(
    fab_links: jax.Array,  # i32[..., N, Hf] fabric link ids, -1 = hop absent
    tx_link: jax.Array,  # i32[...] host_tx link id (shared by the N sub-flows)
    rx_link: jax.Array,  # i32[...] host_rx link id (shared by the N sub-flows)
    rates: jax.Array,  # f32[..., N] offered rate per sub-flow (bps)
    queue: jax.Array,  # f32[n_links + 1]
    capacity: jax.Array,  # f32[n_links + 1] bps (sentinel = 1e30)
    queue_mask: jax.Array,  # f32[n_links + 1]
    *,
    n_links: int,
    kmin: float,
    kmax: float,
    pmax: float,
    dt: float,
    qmax_bytes: float,
    backend: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """NIC-tiered hop cascade: same physics as ``cascade`` but exploiting
    that the N sub-flows of a flow share their first (host_tx) and last
    (host_rx) hop — those two segment-sums run over flows (O(W)) instead of
    sub-flows (O(W*N)), and their scale gathers are per flow.

    Returns (arrival[n_links+1], new_queue[n_links+1], p_mark[n_links+1],
    thr[..., N]).  The flat ``cascade`` stays available as the oracle;
    tiered vs flat agree to float round-off (summation order differs)."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return _cascade_nic_xla(
            fab_links, tx_link, rx_link, rates, queue, capacity, queue_mask,
            n_links=n_links, kmin=kmin, kmax=kmax, pmax=pmax, dt=dt,
            qmax_bytes=qmax_bytes,
        )
    from repro.kernels import linkload as ll

    shape = rates.shape  # [..., N]
    N = shape[-1]
    hf = fab_links.shape[-1]
    arrival_l, newq_l, mark_l, thr = ll.linkload_cascade_tiered(
        fab_links.reshape(-1, N, hf), tx_link.reshape(-1), rx_link.reshape(-1),
        rates.reshape(-1, N), queue[:n_links], capacity[:n_links],
        queue_mask[:n_links], n_links=n_links, kmin=kmin, kmax=kmax,
        pmax=pmax, dt=dt, qmax_bytes=qmax_bytes,
        interpret=(backend == "pallas_interpret"),
    )
    zero = jnp.zeros((1,), jnp.float32)
    arrival = jnp.concatenate([arrival_l, zero])
    new_queue = jnp.concatenate([newq_l, zero])
    p_mark = jnp.concatenate([mark_l, zero])
    return arrival, new_queue, p_mark, thr.reshape(shape)


def _cascade_nic_xla(fab_links, tx_link, rx_link, rates, queue, capacity,
                     queue_mask, *, n_links, kmin, kmax, pmax, dt, qmax_bytes):
    nl = n_links
    N = rates.shape[-1]
    hf = fab_links.shape[-1]
    tx = tx_link.reshape(-1)
    rx = rx_link.reshape(-1)
    r = rates.reshape(-1, N)  # [W, N]
    lid = jnp.where(fab_links >= 0, fab_links, nl)

    # hop 0: host NIC serialization — pre-reduced over the N sub-flows
    tx_load = jax.ops.segment_sum(r.sum(-1), tx, num_segments=nl + 1)
    arrival = tx_load.at[nl].set(0.0)
    scale = jnp.minimum(1.0, capacity / jnp.maximum(tx_load, 1.0))
    r = r * scale[tx][:, None]

    # fabric hops: per sub-flow (paths differ), flat segment-sum over W*N
    rf = r.reshape(-1)
    lidf = lid.reshape(-1, hf)
    for h in range(hf):
        lh = lidf[:, h]
        load_h = jax.ops.segment_sum(rf, lh, num_segments=nl + 1)
        arrival = arrival + load_h.at[nl].set(0.0)
        scale_h = jnp.minimum(1.0, capacity / jnp.maximum(load_h, 1.0))
        rf = rf * scale_h[lh]
    r = rf.reshape(-1, N)

    # last hop: receiver NIC — pre-reduced again
    rx_load = jax.ops.segment_sum(r.sum(-1), rx, num_segments=nl + 1)
    arrival = arrival + rx_load.at[nl].set(0.0)
    scale = jnp.minimum(1.0, capacity / jnp.maximum(rx_load, 1.0))
    thr = r * scale[rx][:, None]

    new_queue = jnp.clip(
        queue + (arrival - capacity) * dt / 8.0, 0.0, qmax_bytes
    ) * queue_mask
    ramp = (new_queue - kmin) / (kmax - kmin)
    p_mark = jnp.where(
        new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax)
    ).astype(jnp.float32)
    p_mark = p_mark.at[nl].set(0.0)
    return arrival, new_queue, p_mark, thr.reshape(rates.shape)


def _cascade_xla(links, rates, queue, capacity, queue_mask, *, n_links,
                 kmin, kmax, pmax, dt, qmax_bytes):
    nl = n_links
    hops = links.shape[-1]
    flat_links = links.reshape(-1, hops)
    lid = jnp.where(flat_links >= 0, flat_links, nl)
    r = rates.reshape(-1)
    arrival = jnp.zeros((nl + 1,), jnp.float32)
    for h in range(hops):
        lh = lid[:, h]
        load_h = jax.ops.segment_sum(r, lh, num_segments=nl + 1)
        arrival = arrival + load_h.at[nl].set(0.0)
        # per-LINK scale, then one gather — the sentinel link has cap 1e30
        # so absent hops land on scale exactly 1.0 (no where() needed)
        scale_h = jnp.minimum(1.0, capacity / jnp.maximum(load_h, 1.0))
        r = r * scale_h[lh]
    new_queue = jnp.clip(
        queue + (arrival - capacity) * dt / 8.0, 0.0, qmax_bytes
    ) * queue_mask
    ramp = (new_queue - kmin) / (kmax - kmin)
    p_mark = jnp.where(
        new_queue < kmin, 0.0, jnp.where(new_queue > kmax, 1.0, ramp * pmax)
    ).astype(jnp.float32)
    p_mark = p_mark.at[nl].set(0.0)
    return arrival, new_queue, p_mark, r.reshape(rates.shape)


def subflow_mark_probs(
    links: jax.Array,  # i32[..., H]
    p_mark: jax.Array,  # f32[n_links + 1]
    n_links: int,
) -> tuple[jax.Array, jax.Array]:
    """(p_sub, p_sub_fabric): probability a packet of the sub-flow is marked
    on any hop / on any FABRIC hop (hops 1..H-2 — the marks the destination
    ToR mirrors back as Congestion Packets)."""
    lid = jnp.where(links >= 0, links, n_links)
    hop_mark = jnp.where(links >= 0, p_mark[lid], 0.0)
    p_sub = 1.0 - jnp.prod(1.0 - hop_mark, axis=-1)
    p_sub_fabric = 1.0 - jnp.prod(1.0 - hop_mark[..., 1:-1], axis=-1)
    return p_sub, p_sub_fabric


def subflow_mark_probs_nic(
    fab_links: jax.Array,  # i32[..., N, Hf]
    tx_link: jax.Array,  # i32[...]
    rx_link: jax.Array,  # i32[...]
    p_mark: jax.Array,  # f32[n_links + 1]
    n_links: int,
) -> tuple[jax.Array, jax.Array]:
    """NIC-tiered twin of ``subflow_mark_probs``: the host hops are shared
    by the N sub-flows, so their mark gathers run per flow; only the fabric
    hops gather per sub-flow.  p_sub_fabric is exactly the fabric product
    (hops 1..H-2 in the flat layout)."""
    lid = jnp.where(fab_links >= 0, fab_links, n_links)
    hop_mark = jnp.where(fab_links >= 0, p_mark[lid], 0.0)
    p_sub_fabric = 1.0 - jnp.prod(1.0 - hop_mark, axis=-1)  # [..., N]
    keep = (1.0 - p_mark[tx_link]) * (1.0 - p_mark[rx_link])  # [...]
    p_sub = 1.0 - keep[..., None] * (1.0 - p_sub_fabric)
    return p_sub, p_sub_fabric


def lossy_gbn_factor(
    fab_links: jax.Array,  # i32[..., N, Hf] fabric link ids, -1 = hop absent
    tx_link: jax.Array,  # i32[...]
    rx_link: jax.Array,  # i32[...]
    loss: jax.Array,  # f32[n_links + 1] per-link packet-loss rate
    *,
    n_links: int,
    window_pkts: float,
) -> jax.Array:
    """Goodput multiplier f32[..., N] for sub-flows crossing LOSSY links
    (faults.LossyLink): each drop rewinds a half go-back-N window on
    average, so goodput deflates by ``gbn_goodput_factor(p_loss, W)``
    while the DCQCN-offered rate keeps riding the wire — the retransmitted
    bytes ARE offered load, which is why the engine applies this factor to
    delivered throughput only (``thr``), never to the rates entering the
    hop cascade.  Per-path p_loss composes across hops exactly like the
    NIC-tiered mark product (``subflow_mark_probs_nic``): survival is the
    product of per-hop survivals, host hops shared by the N sub-flows."""
    from repro.core import gbn

    lid = jnp.where(fab_links >= 0, fab_links, n_links)
    hop_loss = jnp.where(fab_links >= 0, loss[lid], 0.0)
    surv_fab = jnp.prod(1.0 - hop_loss, axis=-1)  # [..., N]
    surv_host = (1.0 - loss[tx_link]) * (1.0 - loss[rx_link])  # [...]
    p_loss = 1.0 - surv_host[..., None] * surv_fab
    return gbn.gbn_goodput_factor(p_loss, window_pkts)


def reorder_gbn_factor(
    topo: Topology,
    pq: jax.Array,  # f32[n, P] per-path queue bytes (path_queue_2tier)
    spray: jax.Array,  # i32[n] paths a flowcell-split chunk straddles (1 = pinned)
    rc0: jax.Array,  # f32[n] per-flow offered rate (sub-flow 0)
    reorder: jax.Array,  # f32 scalar reorder budget in packets (traced operand)
    *,
    mtu_bytes: float,
    jitter_mtus: float,
    window_pkts: float,
    capacity: jax.Array | None = None,  # traced override of topo.capacity
) -> jax.Array:
    """Effective-bytes AMPLIFICATION >= 1 for flowcell-split flows: a chunk
    sprayed over ``spray`` paths sees inter-path skew (queue divergence
    across the straddled paths), and RoCE's go-back-N rewinds a half window
    per out-of-order arrival — so every delivered byte costs
    ``1 + p_ooo * W/2`` wire bytes.  The engine divides delivered ``thr``
    by this factor (retransmitted bytes ARE offered load, exactly the
    ``lossy_gbn_factor`` convention, just spelled as amplification so the
    no-reordering invariant reads ``factor == 1``).

    The skew model is ``drill_gbn_factor``'s, scaled by straddle coverage:
    spraying over k of P paths sees fraction (k-1)/(P-1) of the full
    inter-path spread (k=1 -> no skew, k=P -> the DRILL worst case).  The
    NIC's ``reorder`` budget (packets it can re-sequence before a go-back-N
    fires) buys back ``reorder * MTU / rate`` seconds of skew.  ``reorder``
    is a TRACED scalar so one compiled program covers every budget;
    ``spray`` is traced per-flow data so one program covers every split
    factor.  Exactly 1.0 wherever ``spray <= 1`` (all flowcells on one
    path: no reordering possible, the paper's invariant)."""
    from repro.core import gbn

    P = topo.n_paths
    cap = topo.capacity if capacity is None else capacity
    up_cap = cap[0]  # uplink block starts at 0 (2-tier layout)
    d_path = pq * 8.0 / jnp.maximum(up_cap, 1.0)  # [n, P] seconds
    dmax = jnp.max(d_path, -1)
    dmin = jnp.min(d_path, -1)
    full_spread = dmax - dmin  # skew across ALL P paths
    k = jnp.clip(spray.astype(jnp.float32), 1.0, float(P))
    frac = (k - 1.0) / jnp.float32(max(P - 1, 1))  # [n] straddle coverage
    mean_q = jnp.mean(pq, -1)
    jitter_bytes = jnp.minimum(0.5 * mean_q, jitter_mtus * mtu_bytes)
    jitter = jitter_bytes * 8.0 / jnp.maximum(up_cap, 1.0)
    skew = jnp.maximum(full_spread, jitter) * frac
    budget_s = reorder * mtu_bytes * 8.0 / jnp.maximum(rc0, 1.0)
    eff = jnp.maximum(skew - budget_s, 0.0)
    p_ooo = gbn.ooo_probability(eff, rc0, mtu_bytes)
    amp = 1.0 + p_ooo * (window_pkts / 2.0)
    return jnp.where(spray > 1, amp, 1.0)


def queue_mask_for(topo: Topology) -> jax.Array:
    """1.0 on links that queue and ECN-mark, 0.0 on host_tx (NIC-internal
    backlog, no ECN there) and on the -1 sentinel slot."""
    nl = topo.n_links
    h0 = nl - 2 * topo.n_hosts
    mask = jnp.ones((nl + 1,), jnp.float32)
    mask = mask.at[h0 : h0 + topo.n_hosts].set(0.0)
    return mask.at[nl].set(0.0)


def integrate_queue(
    queue: jax.Array,  # f32[n_links + 1]
    arrival: jax.Array,  # f32[n_links + 1]
    capacity: jax.Array,  # f32[n_links + 1]
    queue_mask: jax.Array,  # f32[n_links + 1]
    dparams,
    *,
    dt: float,
    qmax_bytes: float,
    n_links: int,
) -> tuple[jax.Array, jax.Array]:
    """Queue integration + RED/ECN marks for dataplanes that compute their
    own arrival vector (DRILL's spray).  cascade() fuses the same update."""
    from repro.netsim import dcqcn as dcqcn_mod

    new_queue = jnp.clip(
        queue + (arrival - capacity) * dt / 8.0, 0.0, qmax_bytes
    ) * queue_mask
    p_mark = dcqcn_mod.mark_probability(new_queue, dparams).at[n_links].set(0.0)
    return new_queue, p_mark


def queue_fast_forward(
    queue: jax.Array,  # f32[n_links + 1]
    arrival: jax.Array,  # f32[n_links + 1] offered bps, constant over the span
    capacity: jax.Array,  # f32[n_links + 1]
    queue_mask: jax.Array,  # f32[n_links + 1]
    *,
    dt: float,
    n_steps: int,  # static span length
    qmax_bytes: float,
    n_links: int,
) -> tuple[jax.Array, jax.Array]:
    """Analytic ``n_steps``-step queue trajectory under CONSTANT arrivals.

    The per-step update ``q <- clip(q + delta, 0, qmax) * mask`` with a
    constant ``delta = (arrival - capacity) * dt/8`` is monotone in the
    step count, so clipping commutes with accumulation and step ``m`` is
    exactly ``clip(q0 + m*delta, 0, qmax) * mask`` (modulo f32 rounding of
    the product vs the iterated sum).  Used by the compact engine's
    quiescence fast-forward (DESIGN.md §15), whose predicate additionally
    guarantees no masked queue crosses the ECN kmin margin mid-span.

    Returns ``(q_final[n_links+1], max_queue_traj[n_steps])`` where the
    trajectory entry ``m`` is the max over real links after ``m+1`` steps
    (matching the per-step ``max_queue`` StepOutputs channel).
    """
    delta = (arrival - capacity) * (dt / 8.0)
    m = jnp.arange(1, n_steps + 1, dtype=jnp.float32)[:, None]
    traj = jnp.clip(queue[None, :] + m * delta[None, :], 0.0, qmax_bytes)
    traj = traj * queue_mask[None, :]
    return traj[-1], jnp.max(traj[:, :n_links], axis=1)


# ------------------------------------------------------------------ DRILL
def drill_spray(
    topo: Topology,
    queue: jax.Array,  # f32[n_links + 1]
    rc0: jax.Array,  # f32[n] per-flow offered rate (sub-flow 0)
    src: jax.Array,  # i32[n] source hosts
    dst: jax.Array,  # i32[n]
    src_leaf: jax.Array,  # i32[n]
    dst_leaf: jax.Array,  # i32[n]
    active0: jax.Array,  # bool[n, 1]
    drill_q0: float,
    capacity: jax.Array | None = None,  # traced override of topo.capacity
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """DRILL's per-packet spray on a 2-tier Clos: inverse-queue weights over
    all paths, cascaded host_tx -> uplink -> downlink -> host_rx.

    The per-leaf reductions and gathers run as one-hot matmuls over the
    (tiny) leaf axis — [n, L] gemms beat XLA:CPU's serial scatter-add on
    the [n, P] operands by ~2x at DRILL's collapsed-window sizes, and the
    one-hot gather back is exact (one 1.0 term, L-1 exact +0.0 terms).

    Returns (arrival[n_links+1], thr[n] delivered rate before the go-back-N
    penalty, w[n, P] path weights, pq[n, P] per-path queue bytes).
    """
    from repro.core import baselines

    cap = topo.capacity if capacity is None else capacity
    nl = topo.n_links
    L_, S_ = topo.n_leaf, topo.n_paths
    h0 = nl - 2 * topo.n_hosts
    up0 = 0
    pq = path_queue_2tier(topo, queue, src_leaf, dst_leaf)  # [n, P]
    w = baselines.drill_weights(pq, drill_q0) * active0
    oh_s = (src_leaf[:, None] == jnp.arange(L_)[None, :]).astype(jnp.float32)
    oh_d = (dst_leaf[:, None] == jnp.arange(L_)[None, :]).astype(jnp.float32)
    arrival = jnp.zeros((nl + 1,), jnp.float32)
    # hop 0: host NIC
    tx_load = jax.ops.segment_sum(rc0, src, num_segments=topo.n_hosts)
    arrival = arrival.at[h0 : h0 + topo.n_hosts].add(tx_load)
    s_tx = jnp.minimum(1.0, cap[h0 + src] / jnp.maximum(tx_load[src], 1.0))
    r0 = rc0 * s_tx  # [n]
    # hop 1: uplinks (per-path split)
    r0w = r0[:, None] * w  # [n, P]
    up_load = oh_s.T @ r0w  # [L, P]
    arrival = arrival.at[up0 : up0 + L_ * S_].add(up_load.reshape(-1))
    cap_up = cap[up0 : up0 + L_ * S_].reshape(L_, S_)
    s_up = jnp.minimum(1.0, cap_up / jnp.maximum(up_load, 1.0))
    r1 = r0w * (oh_s @ s_up)  # [n, P]
    # hop 2: downlinks
    dn_load = oh_d.T @ r1  # [L, P] (by dst)
    arrival = arrival.at[L_ * S_ : 2 * L_ * S_].add(dn_load.T.reshape(-1))
    cap_dn = cap[L_ * S_ : 2 * L_ * S_].reshape(S_, L_)
    s_dn = jnp.minimum(1.0, cap_dn.T / jnp.maximum(dn_load, 1.0))  # [L, P]
    r2 = r1 * (oh_d @ s_dn)  # [n, P]
    # hop 3: receiver NIC
    r2sum = jnp.sum(r2, -1)
    rx_load = jax.ops.segment_sum(r2sum, dst, num_segments=topo.n_hosts)
    arrival = arrival.at[h0 + topo.n_hosts : h0 + 2 * topo.n_hosts].add(rx_load)
    s_rx = jnp.minimum(
        1.0, cap[h0 + topo.n_hosts + dst] / jnp.maximum(rx_load[dst], 1.0)
    )
    thr = r2sum * s_rx  # [n]
    return arrival, thr, w, pq


def drill_mark_probs(
    topo: Topology,
    p_mark: jax.Array,  # f32[n_links + 1]
    w: jax.Array,  # f32[n, P]
    src_leaf: jax.Array,
    dst_leaf: jax.Array,
    dst: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """(p_sub[n, 1], p_sub_fabric[n, 1]) for DRILL's weighted spray."""
    nl = topo.n_links
    L_, S_ = topo.n_leaf, topo.n_paths
    h0 = nl - 2 * topo.n_hosts
    pm_up = p_mark[0 : L_ * S_].reshape(L_, S_)[src_leaf]
    pm_dn = p_mark[L_ * S_ : 2 * L_ * S_].reshape(S_, L_).T[dst_leaf]
    pm_fab = 1.0 - (1.0 - pm_up) * (1.0 - pm_dn)  # [n, P]
    p_sub_fabric = jnp.sum(w * pm_fab, -1, keepdims=True)
    p_host = p_mark[h0 + topo.n_hosts + dst]
    p_sub = 1.0 - (1.0 - p_sub_fabric) * (1.0 - p_host[:, None])
    return p_sub, p_sub_fabric


def drill_gbn_factor(
    topo: Topology,
    pq: jax.Array,  # f32[n, P] per-path queue bytes
    w: jax.Array,  # f32[n, P] spray weights
    rc0: jax.Array,  # f32[n] offered rate
    *,
    mtu_bytes: float,
    jitter_mtus: float,
    window_pkts: float,
    capacity: jax.Array | None = None,  # traced override of topo.capacity
) -> jax.Array:
    """Go-back-N goodput penalty for DRILL's spray: packets of ONE QP sprayed
    over paths whose queueing delays differ get reordered; even with equal
    AVERAGE queues, per-packet occupancy jitter of O(queue) reorders at high
    rate.  spread = max over used paths of |delay - min|, floored by the
    jitter of the mean queue.  Returns the goodput multiplier f32[n]."""
    from repro.core import gbn

    P = topo.n_paths
    cap = topo.capacity if capacity is None else capacity
    up_cap = cap[0]  # uplink block starts at 0 (2-tier layout)
    d_path = pq * 8.0 / jnp.maximum(up_cap, 1.0)  # [n, P] seconds
    used = w > (0.5 / P)
    dmax = jnp.max(jnp.where(used, d_path, -jnp.inf), -1)
    dmin = jnp.min(jnp.where(used, d_path, jnp.inf), -1)
    spread = jnp.where(jnp.isfinite(dmax) & jnp.isfinite(dmin), dmax - dmin, 0.0)
    mean_q = jnp.sum(jnp.where(used, pq, 0.0), -1) / jnp.maximum(jnp.sum(used, -1), 1)
    jitter_bytes = jnp.minimum(0.5 * mean_q, jitter_mtus * mtu_bytes)
    jitter = jitter_bytes * 8.0 / jnp.maximum(up_cap, 1.0)
    p_ooo = gbn.ooo_probability(jnp.maximum(spread, jitter), rc0, mtu_bytes)
    return gbn.gbn_goodput_factor(p_ooo, window_pkts)


def path_queue_2tier(topo: Topology, queue, src_leaf, dst_leaf) -> jax.Array:
    """Queue bytes along each (up, down) path for every flow: f32[n, P]."""
    S, L = topo.n_paths, topo.n_leaf
    q_up = queue[0 : L * S].reshape(L, S)
    q_dn = queue[L * S : 2 * L * S].reshape(S, L)
    return q_up[src_leaf] + q_dn[:, :].T[dst_leaf]
