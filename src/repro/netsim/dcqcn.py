"""Fluid DCQCN rate control (Zhu et al., SIGCOMM'15) — paper §IV "Flow CC".

Per sub-flow state: current rate rc, target rate rt, alpha, and two timers.
Per step the engine feeds each sub-flow the probability that at least one of
its packets was ECN-marked during the step; a (deterministic, counter-hash)
Bernoulli draw decides whether a CNP fires (CNPs are generated at most once
per ``cnp_interval``).

  on CNP:   rt <- rc;  rc <- rc*(1 - alpha/2);  alpha <- (1-g)alpha + g
  no CNP:   alpha decays every ``alpha_interval``;
            every ``rate_interval``: 5 stages of fast recovery
            rc <- (rc+rt)/2, then additive increase rt += r_ai.

Paper parameter sets: (Kmin,Kmax,Pmax) = (160KB,520KB,0.2) @40G testbed and
(400KB,1600KB,0.2) @100G sim, from HPCC's recommendations [31].
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np



class DCQCNParams(NamedTuple):
    kmin_bytes: float = 400e3
    kmax_bytes: float = 1600e3
    pmax: float = 0.2
    g: float = 1.0 / 256.0
    r_ai: float = 1e9  # additive increase (bps); HPCC-style tuning for 100G
    min_rate: float = 1e9
    cnp_interval: float = 50e-6
    alpha_interval: float = 55e-6
    rate_interval: float = 55e-6
    mtu_bytes: float = 1000.0


class DCQCNState(NamedTuple):
    rc: jax.Array  # f32[...] current rate (bps)
    rt: jax.Array  # f32[...] target rate
    alpha: jax.Array  # f32[...]
    t_since_cnp: jax.Array  # f32[...]
    t_since_rate: jax.Array  # f32[...]
    recovery_stage: jax.Array  # f32[...] (blended)


def init_state(shape, line_rate: float) -> DCQCNState:
    f = lambda v: jnp.full(shape, v, jnp.float32)
    return DCQCNState(
        rc=f(line_rate),
        rt=f(line_rate),
        alpha=f(1.0),
        t_since_cnp=f(1.0),
        t_since_rate=f(0.0),
        recovery_stage=jnp.zeros(shape, jnp.float32),
    )


def mark_probability(queue_bytes: jax.Array, p: DCQCNParams) -> jax.Array:
    """RED-style ECN marking probability from instantaneous queue depth."""
    ramp = (queue_bytes - p.kmin_bytes) / (p.kmax_bytes - p.kmin_bytes)
    return jnp.where(
        queue_bytes < p.kmin_bytes,
        0.0,
        jnp.where(queue_bytes > p.kmax_bytes, 1.0, ramp * p.pmax),
    ).astype(jnp.float32)


def step(
    state: DCQCNState,
    mark_frac: jax.Array,  # f32[...] per-packet mark prob seen this step
    active: jax.Array,  # bool[...]
    dt: float,
    line_rate: jax.Array | float,
    p: DCQCNParams,
    step_idx: jax.Array = None,  # kept for API compat; unused (deterministic)
    flow_salt: jax.Array = None,
) -> tuple[DCQCNState, jax.Array]:
    """One fluid step — the ODE (expected-value) form of DCQCN.

    ``e`` = probability that a CNP fires this step; the CNP branch and the
    recovery branch are blended with weight ``e``.  Deterministic: two
    sub-flows on identical paths evolve identically (no sampling-noise
    stragglers, which a fluid model must not have — a packet simulator
    averages this noise over thousands of packets per interval).
    Returns (new_state, e).
    """
    pkts = jnp.maximum(state.rc * dt / (8.0 * p.mtu_bytes), 1.0)
    p_any = 1.0 - jnp.exp(pkts * jnp.log1p(-jnp.minimum(mark_frac, 0.999)))
    gate = (state.t_since_cnp >= p.cnp_interval) & active
    e = jnp.where(gate, p_any, 0.0).astype(jnp.float32)

    # --- CNP branch
    rt_c = state.rc
    rc_c = jnp.maximum(state.rc * (1.0 - state.alpha / 2.0), p.min_rate)
    alpha_c = (1.0 - p.g) * state.alpha + p.g

    # --- no-CNP branch
    t_rate = state.t_since_rate + dt
    do_rate = t_rate >= p.rate_interval
    in_recovery = state.recovery_stage < 5.0
    rc_n = jnp.where(do_rate, (state.rc + state.rt) / 2.0, state.rc)
    rt_n = jnp.where(do_rate & ~in_recovery, state.rt + p.r_ai, state.rt)
    rt_n = jnp.minimum(rt_n, line_rate)
    rc_n = jnp.minimum(rc_n, line_rate)
    stage_n = jnp.where(do_rate, state.recovery_stage + 1.0, state.recovery_stage)
    alpha_n = state.alpha * jnp.float32(1.0 - p.g) ** jnp.float32(dt / p.alpha_interval)

    blend = lambda c, n: e * c + (1.0 - e) * n
    new = DCQCNState(
        rc=blend(rc_c, rc_n),
        rt=blend(rt_c, rt_n),
        alpha=blend(alpha_c, alpha_n),
        t_since_cnp=blend(jnp.zeros_like(e), state.t_since_cnp + dt),
        t_since_rate=blend(jnp.zeros_like(e), jnp.where(do_rate, 0.0, t_rate)),
        recovery_stage=blend(jnp.zeros_like(e), stage_n),
    )
    # inactive sub-flows hold full rate so they start at line rate
    new = jax.tree.map(lambda a, b: jnp.where(active, a, b), new, state)
    return new, e


def fast_forward(
    state: DCQCNState,
    active: jax.Array,  # bool[...]
    n_steps: jax.Array | int,  # number of dt steps to advance
    dt: float,
    p: DCQCNParams,
) -> DCQCNState:
    """Advance ``n_steps`` fixed-dt steps in closed form — zero marks only.

    Valid under the compact engine's quiescence predicate (DESIGN.md §15):
    every hop's mark probability is zero for the whole span and every
    active sub-flow sits pinned at ``rc == rt == line rate``.  Then the
    per-step update reduces to pure timer bookkeeping — rc/rt are exact
    fixed points of the recovery branch, alpha decays geometrically, the
    rate timer is periodic, and recovery-stage increments are no-ops until
    the next CNP resets them — so ``n`` scan iterations collapse to O(1).
    Inactive sub-flows hold state exactly, as in :func:`step`.
    """
    n = jnp.asarray(n_steps, jnp.float32)
    decay = jnp.float32(1.0 - p.g) ** jnp.float32(dt / p.alpha_interval)
    alpha = state.alpha * decay**n
    # the rate timer climbs dt per step and resets to 0 on crossing
    # rate_interval: first event at m1 = max(ceil((I - t0)/dt), 1), then
    # every P = ceil(I/dt) steps; final timer value is the residual.
    period = jnp.float32(np.ceil(p.rate_interval / dt))
    m1 = jnp.maximum(jnp.ceil((p.rate_interval - state.t_since_rate) / dt), 1.0)
    fired = n >= m1
    events = jnp.where(fired, 1.0 + jnp.floor((n - m1) / period), 0.0)
    t_rate = jnp.where(
        fired,
        jnp.mod(n - m1, period) * jnp.float32(dt),
        state.t_since_rate + n * jnp.float32(dt),
    )
    new = DCQCNState(
        rc=state.rc,
        rt=state.rt,
        alpha=alpha,
        t_since_cnp=state.t_since_cnp + n * jnp.float32(dt),
        t_since_rate=t_rate,
        recovery_stage=state.recovery_stage + events,
    )
    return jax.tree.map(lambda a, b: jnp.where(active, a, b), new, state)
