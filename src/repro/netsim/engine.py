"""Fluid flow-level datacenter simulator — the NS3-equivalent (paper §IV).

One jitted ``lax.scan`` over time steps of ``dt``.  The whole datacenter is
a pytree: per-sub-flow transfer state, per-link queues, DCQCN rate state,
and (for SeqBalance) the source-ToR Congestion Tables.  Five schemes share
the step function; scheme choice is a *static* argument so each scheme
compiles to its own specialized program.

Fluid model recap (DESIGN.md §8):
  offered[l]  = sum of sub-flow DCQCN rates crossing link l
  scale[l]    = min(1, cap[l]/offered[l])           (switch serves at cap)
  goodput_sf  = rc * min over the sub-flow's hops of scale
  q[l]       += (offered[l] - cap[l])+ * dt          (congestion signal)
  ECN mark    : RED ramp on q;   DCQCN reacts per sub-flow
  SeqBalance  : fabric marks are mirrored to the source ToR as Congestion
                Packets -> CongestionTable inactive for phi; NEW sub-flows
                double-hash around inactive paths; placed sub-flows never
                move (=> no reordering by construction).
  DRILL       : per-packet spray -> per-step inverse-queue weights over all
                paths; pays the go-back-N goodput penalty (core/gbn.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, congestion_table as ctab, gbn, hashing, routing, shaper
from repro.netsim import dcqcn as dcqcn_mod
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace

SCHEMES = ("seqbalance", "ecmp", "letflow", "conga", "drill")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    scheme: str = "seqbalance"
    n_sub: int = 4  # N (SeqBalance Shaper); forced to 1 for other schemes
    min_split_bytes: float = 16e3  # Shaper floor: WQEs below this stay whole
    phi: float = 32e-6
    flowlet_timeout: float = 100e-6
    dt: float = 10e-6
    duration_s: float = 20e-3
    dcqcn: dcqcn_mod.DCQCNParams = dcqcn_mod.DCQCNParams()
    gbn_window_pkts: float = 16.0
    drill_jitter_mtus: float = 4.0
    drill_q0: float = 1500.0
    mark_salt: int = 0xA5A5
    qmax_bytes: float = 8e6
    # a path is declared congested when at least this many ECN-marked
    # packets are mirrored back to the source ToR within one step (the
    # expected-marks intensity; deterministic, avoids mark-noise herding)
    cong_threshold_pkts: float = 1.0

    def __post_init__(self):
        assert self.scheme in SCHEMES, self.scheme
        if self.scheme != "seqbalance":
            object.__setattr__(self, "n_sub", 1)


class SimState(NamedTuple):
    remaining: jax.Array  # f32[F, N] bytes
    path: jax.Array  # i32[F, N]
    assigned: jax.Array  # bool[F]
    sub_done: jax.Array  # bool[F, N]
    finish: jax.Array  # f32[F] (+inf until CQE)
    cc: dcqcn_mod.DCQCNState  # [F, N]
    table: ctab.CongestionTable  # [n_leaf, n_paths]
    queue: jax.Array  # f32[n_links+1]
    cqe: shaper.CQEState  # [F]
    cnp_pkts: jax.Array  # f32 scalar — Congestion Packet counter (Table II)
    step: jax.Array  # i32


class StepOutputs(NamedTuple):
    uplink_load: jax.Array  # f32[n_leaf, n_uplinks] offered bps
    goodput_total: jax.Array  # f32 scalar bps (sum of delivered)
    cnp_rate: jax.Array  # f32 congestion packets this step
    max_queue: jax.Array  # f32 bytes


def _u32(x):
    return jnp.asarray(x, jnp.uint32)


def build_sim(topo: Topology, cfg: SimConfig, trace: Trace):
    """Returns (init_state, step_fn, static) for the given scheme/topo/trace."""
    F = len(trace.sizes)
    N = cfg.n_sub
    P = topo.n_paths
    hpl = topo.hosts_per_leaf

    sizes = jnp.asarray(trace.sizes)
    arrivals = jnp.asarray(trace.arrivals)
    src = jnp.asarray(trace.src)
    dst = jnp.asarray(trace.dst)
    fid = jnp.asarray(trace.flow_id)
    valid = jnp.asarray(trace.valid)
    src_leaf = src // hpl
    dst_leaf = dst // hpl

    sub_sizes = shaper.split_wqe(sizes, N)  # f32[F, N]
    if N > 1:
        # The Shaper only segments WQEs worth segmenting: below the floor a
        # message rides a single QP (sub-WQE 0); its sibling slots carry
        # zero bytes and are born completed (their CQE bits set trivially).
        whole = jnp.concatenate(
            [sizes[:, None], jnp.zeros((F, N - 1), sizes.dtype)], axis=1
        )
        split_mask = (sizes >= cfg.min_split_bytes)[:, None]
        sub_sizes = jnp.where(split_mask, sub_sizes, whole)
    # five-tuples: SeqBalance -> per-sub-flow QPs; others -> per-flow
    s5 = shaper.subflow_five_tuples(src, dst, fid, N)  # each [F, N]
    f5 = (_u32(src), _u32(dst), _u32(0xB000) + (hashing.fmix32(fid) % _u32(0x3FFF)),
          jnp.full((F,), 4791, jnp.uint32))
    sub_salt = hashing.fmix32(s5[2] ^ (_u32(fid)[:, None] * _u32(2246822519)))  # [F,N]
    line_rate = topo.capacity[topo.n_links - 2 * topo.n_hosts]  # host_tx[0] bw

    if cfg.scheme in ("conga", "drill"):
        assert topo.kind == "leaf_spine", f"{cfg.scheme} is 2-tier only (paper §IV.B)"

    nl = topo.n_links

    def init_state() -> SimState:
        return SimState(
            remaining=sub_sizes,
            path=jnp.full((F, N), -1, jnp.int32),
            assigned=jnp.zeros((F,), bool),
            sub_done=sub_sizes <= 0.0,
            finish=jnp.full((F,), jnp.inf, jnp.float32),
            cc=dcqcn_mod.init_state((F, N), line_rate),
            table=ctab.CongestionTable.create(topo.n_leaf, P),
            queue=jnp.zeros((nl + 1,), jnp.float32),
            cqe=shaper.CQEState.create(F, N),
            cnp_pkts=jnp.zeros((), jnp.float32),
            step=jnp.zeros((), jnp.int32),
        )

    up0 = 0  # uplink block offset (leaf_spine); three_tier shares layout idea
    dparams = cfg.dcqcn

    def _path_queue_2tier(queue, sleaf, dleaf):
        """q along each path for every flow: f32[F, P] (2-tier only)."""
        S = P
        L = topo.n_leaf
        q_up = queue[up0 : up0 + L * S].reshape(L, S)
        q_dn = queue[L * S : 2 * L * S].reshape(S, L)
        return q_up[sleaf] + q_dn[:, :].T[dleaf]  # [F,P]

    def _path_scale_2tier(scale, sleaf, dleaf):
        S = P
        L = topo.n_leaf
        s_up = scale[up0 : up0 + L * S].reshape(L, S)
        s_dn = scale[L * S : 2 * L * S].reshape(S, L)
        return jnp.minimum(s_up[sleaf], s_dn.T[dleaf])  # [F,P]

    def step_fn(state: SimState, _=None):
        t = state.step.astype(jnp.float32) * cfg.dt
        arrived = valid & (t >= arrivals)
        newly = arrived & ~state.assigned
        active_flow = state.assigned & jnp.isinf(state.finish)

        # ---------------- path (re)assignment ----------------
        path = state.path
        if cfg.scheme == "seqbalance":
            inact = ctab.inactive_matrix(state.table, t)  # [L, P]
            # Congestion that is GLOBAL carries no routing signal: if more
            # than half of a ToR's paths are marked, avoiding the marked
            # ones just herds arrivals onto the remainder.  Treat the table
            # as stale in that case and fall back to the plain hash (the
            # paper's table is only ever differential: "the stored
            # information pertains only to paths experiencing congestion").
            stale = inact.sum(-1, keepdims=True) > (P // 2)
            inact = jnp.where(stale, False, inact)
            rows = inact[src_leaf][:, None, :]  # [F,1,P]
            rows = jnp.broadcast_to(rows, (F, N, P))
            p_new = routing.select_paths(*s5, rows, P)  # [F,N]
            path = jnp.where(newly[:, None], p_new, path)
        elif cfg.scheme == "ecmp":
            p_new = routing.ecmp_paths(*f5, P)[:, None]
            path = jnp.where(newly[:, None], p_new, path)
        elif cfg.scheme in ("letflow", "conga"):
            rng = hashing.fmix32(fid ^ _u32(state.step) * _u32(0x85EBCA77))
            p_init = routing.ecmp_paths(*f5, P)
            gap = baselines.flowlet_gap_occurs(
                state.cc.rc[:, 0], dparams.mtu_bytes, cfg.flowlet_timeout
            )
            if cfg.scheme == "letflow":
                p_re = baselines.letflow_paths(path[:, 0], gap, rng, P)
            else:
                # CONGA reroutes to the least-congested path, but only at a
                # flowlet boundary; initial placement stays hash-based (the
                # fluid model would otherwise herd every same-step arrival
                # onto one path, which the real per-flowlet DRE feedback
                # does not do).
                pq = _path_queue_2tier(state.queue, src_leaf, dst_leaf)
                p_re = baselines.conga_paths(path[:, 0], gap, pq)
            p_next = jnp.where(newly, p_init, jnp.where(active_flow, p_re, path[:, 0]))
            path = p_next[:, None]
        else:  # drill: nominal path 0; real split via weights below
            path = jnp.where(newly[:, None], 0, path)
        assigned = state.assigned | newly

        active = assigned[:, None] & ~state.sub_done & jnp.isinf(state.finish)[:, None]
        # a sub-flow can never offer more than the bytes it still has to send
        # (a 4 KB message is a 0.3 us burst at 100G, not a full dt of line rate)
        rc = jnp.where(
            active, jnp.minimum(state.cc.rc, state.remaining * 8.0 / cfg.dt), 0.0
        )  # [F,N]

        # -------- offered load, cascaded hop-by-hop (NIC serializes first,
        # then fabric: a hop's arrivals are the UPSTREAM-scaled rates, so a
        # host can never inject more than its NIC line rate into the fabric)
        links = topo.subflow_links(src[:, None], dst[:, None], path)  # [F,N,6]
        lid = jnp.where(links >= 0, links, nl)
        h0 = nl - 2 * topo.n_hosts  # host_tx block offset

        if cfg.scheme == "drill":
            pq = _path_queue_2tier(state.queue, src_leaf, dst_leaf)  # [F,P]
            w = baselines.drill_weights(pq, cfg.drill_q0) * active[:, 0:1]
            L_, S_ = topo.n_leaf, P
            arrival = jnp.zeros((nl + 1,), jnp.float32)
            # hop 0: host NIC
            tx_load = jax.ops.segment_sum(rc[:, 0], src, num_segments=topo.n_hosts)
            arrival = arrival.at[h0 : h0 + topo.n_hosts].add(tx_load)
            s_tx = jnp.minimum(1.0, topo.capacity[h0 + src] / jnp.maximum(tx_load[src], 1.0))
            r0 = rc[:, 0] * s_tx  # [F]
            # hop 1: uplinks (per-path split)
            r0w = r0[:, None] * w  # [F,P]
            up_load = jax.ops.segment_sum(r0w, src_leaf, num_segments=L_)  # [L,P]
            arrival = arrival.at[up0 : up0 + L_ * S_].add(up_load.reshape(-1))
            cap_up = topo.capacity[up0 : up0 + L_ * S_].reshape(L_, S_)
            s_up = jnp.minimum(1.0, cap_up / jnp.maximum(up_load, 1.0))
            r1 = r0w * s_up[src_leaf]  # [F,P]
            # hop 2: downlinks
            dn_load = jax.ops.segment_sum(r1, dst_leaf, num_segments=L_)  # [L,P] (by dst)
            arrival = arrival.at[L_ * S_ : 2 * L_ * S_].add(dn_load.T.reshape(-1))
            cap_dn = topo.capacity[L_ * S_ : 2 * L_ * S_].reshape(S_, L_)
            s_dn = jnp.minimum(1.0, cap_dn.T / jnp.maximum(dn_load, 1.0))  # [L,P]
            r2 = r1 * s_dn[dst_leaf]  # [F,P]
            # hop 3: receiver NIC
            r2sum = jnp.sum(r2, -1)
            rx_load = jax.ops.segment_sum(r2sum, dst, num_segments=topo.n_hosts)
            arrival = arrival.at[h0 + topo.n_hosts : h0 + 2 * topo.n_hosts].add(rx_load)
            s_rx = jnp.minimum(
                1.0, topo.capacity[h0 + topo.n_hosts + dst] / jnp.maximum(rx_load[dst], 1.0)
            )
            thr = r2sum * s_rx  # [F]
        else:
            r = rc  # [F,N]
            arrival = jnp.zeros((nl + 1,), jnp.float32)
            for h in range(6):
                lh = lid[:, :, h]
                load_h = jax.ops.segment_sum(r.reshape(-1), lh.reshape(-1), num_segments=nl + 1)
                arrival = arrival + load_h.at[nl].set(0.0)
                s_h = jnp.minimum(1.0, topo.capacity[lh] / jnp.maximum(load_h[lh], 1.0))
                r = r * jnp.where(links[:, :, h] >= 0, s_h, 1.0)
            thr = r  # [F,N] delivered rate after all hops

        new_queue = jnp.clip(
            state.queue + (arrival - topo.capacity) * cfg.dt / 8.0, 0.0, cfg.qmax_bytes
        )
        # host_tx backlog is NIC-internal (no ECN there); switch queues mark.
        new_queue = new_queue.at[h0 : h0 + topo.n_hosts].set(0.0)
        p_mark = dcqcn_mod.mark_probability(new_queue, dparams)  # [nl+1]
        p_mark = p_mark.at[nl].set(0.0)

        # ---------------- per-sub-flow ECN marks ----------------
        if cfg.scheme == "drill":
            L_, S_ = topo.n_leaf, P
            pm_up = p_mark[up0 : up0 + L_ * S_].reshape(L_, S_)[src_leaf]
            pm_dn = p_mark[L_ * S_ : 2 * L_ * S_].reshape(S_, L_).T[dst_leaf]
            pm_fab = 1.0 - (1.0 - pm_up) * (1.0 - pm_dn)  # [F,P]
            p_sub_fabric = jnp.sum(w * pm_fab, -1, keepdims=True)
            p_host = p_mark[h0 + topo.n_hosts + dst]
            p_sub = 1.0 - (1.0 - p_sub_fabric) * (1.0 - p_host[:, None])
            # go-back-N penalty: packets of ONE QP sprayed over paths whose
            # queueing delays differ get reordered; even with equal AVERAGE
            # queues, per-packet occupancy jitter of O(queue) reorders at
            # high rate.  spread = max over used paths of |delay - min|,
            # floored by the jitter of the mean queue.
            d_path = pq * 8.0 / jnp.maximum(topo.capacity[up0], 1.0)  # [F,P] seconds
            used = w > (0.5 / P)
            dmax = jnp.max(jnp.where(used, d_path, -jnp.inf), -1)
            dmin = jnp.min(jnp.where(used, d_path, jnp.inf), -1)
            spread = jnp.where(jnp.isfinite(dmax) & jnp.isfinite(dmin), dmax - dmin, 0.0)
            mean_q = jnp.sum(jnp.where(used, pq, 0.0), -1) / jnp.maximum(
                jnp.sum(used, -1), 1
            )
            jitter_bytes = jnp.minimum(0.5 * mean_q, cfg.drill_jitter_mtus * dparams.mtu_bytes)
            jitter = jitter_bytes * 8.0 / jnp.maximum(topo.capacity[up0], 1.0)
            p_ooo = gbn.ooo_probability(jnp.maximum(spread, jitter), rc[:, 0], dparams.mtu_bytes)
            thr = thr * gbn.gbn_goodput_factor(p_ooo, cfg.gbn_window_pkts)
            thr = thr[:, None]  # [F,1]
        else:
            hop_mark = jnp.where(links >= 0, p_mark[lid], 0.0)
            p_sub = 1.0 - jnp.prod(1.0 - hop_mark, axis=-1)  # [F,N]
            fabric = links[..., 1:5]
            fab_mark = jnp.where(fabric >= 0, p_mark[jnp.where(fabric >= 0, fabric, nl)], 0.0)
            p_sub_fabric = 1.0 - jnp.prod(1.0 - fab_mark, axis=-1)

        # ---------------- transfer progress & CQE ----------------
        delivered = thr * cfg.dt / 8.0  # bytes
        new_remaining = jnp.maximum(state.remaining - jnp.where(active, delivered, 0.0), 0.0)
        sub_done = assigned[:, None] & (new_remaining <= 0.0)
        cqe = shaper.ack_mask(state.cqe, sub_done)
        all_done = shaper.cqe_ready(cqe) & assigned & valid
        finish = jnp.where(jnp.isinf(state.finish) & all_done, t + cfg.dt, state.finish)

        # ---------------- DCQCN ----------------
        flow_salt = sub_salt if cfg.scheme == "seqbalance" else sub_salt[:, :1]
        flow_salt = jnp.broadcast_to(flow_salt, (F, N))
        cc, _ = dcqcn_mod.step(
            state.cc, p_sub, active, cfg.dt, line_rate, dparams, state.step, flow_salt
        )

        # ---------------- SeqBalance Congestion Packets ----------------
        table = state.table
        pkts = jnp.where(active, rc * cfg.dt / (8.0 * dparams.mtu_bytes), 0.0)
        exp_cong_pkts = jnp.sum(pkts * p_sub_fabric)  # mirrored-packet count
        if cfg.scheme == "seqbalance":
            # expected number of marked data packets per (source ToR, path)
            # this step = expected Congestion Packets mirrored back; the
            # source ToR marks the path inactive when at least one arrives.
            intensity = jnp.zeros((topo.n_leaf, P), jnp.float32)
            idx_leaf = jnp.broadcast_to(src_leaf[:, None], (F, N)).reshape(-1)
            idx_path = jnp.clip(path, 0, P - 1).reshape(-1)
            intensity = intensity.at[idx_leaf, idx_path].add(
                (pkts * p_sub_fabric).reshape(-1)
            )
            dense = intensity >= cfg.cong_threshold_pkts
            table = ctab.mark_congested_dense(table, dense, t, cfg.phi)

        new_state = SimState(
            remaining=new_remaining,
            path=path,
            assigned=assigned,
            sub_done=sub_done,
            finish=finish,
            cc=cc,
            table=table,
            queue=new_queue,
            cqe=cqe,
            cnp_pkts=state.cnp_pkts + exp_cong_pkts,
            step=state.step + 1,
        )
        out = StepOutputs(
            uplink_load=arrival[jnp.asarray(topo.uplink_ids)],
            goodput_total=jnp.sum(jnp.where(active, thr, 0.0)),
            cnp_rate=exp_cong_pkts,
            max_queue=jnp.max(new_queue[:nl]),
        )
        return new_state, out

    return init_state, step_fn


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run(topo: Topology, cfg: SimConfig, trace_arrays):
    trace = Trace(*trace_arrays)
    init_state, step_fn = build_sim(topo, cfg, trace)
    n_steps = int(round(cfg.duration_s / cfg.dt))
    final, outs = jax.lax.scan(step_fn, init_state(), None, length=n_steps)
    return final, outs


def simulate(topo: Topology, cfg: SimConfig, trace: Trace) -> tuple[SimState, StepOutputs]:
    """Run the fluid simulation; returns (final_state, per-step outputs)."""
    arrays = (trace.sizes, trace.arrivals, trace.src, trace.dst, trace.flow_id, trace.valid)
    arrays = tuple(jnp.asarray(a) for a in arrays)
    return _run(topo, cfg, arrays)
