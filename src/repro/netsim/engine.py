"""Fluid flow-level datacenter simulator — the NS3-equivalent (paper §IV).

One jitted ``lax.scan`` over time steps of ``dt``.  The whole datacenter is
a pytree: per-sub-flow transfer state, per-link queues, DCQCN rate state,
and (for SeqBalance) the source-ToR Congestion Tables.  Five schemes share
the step function; scheme choice is a *static* argument so each scheme
compiles to its own specialized program.

Fluid model recap (DESIGN.md §8):
  offered[l]  = sum of sub-flow DCQCN rates crossing link l
  scale[l]    = min(1, cap[l]/offered[l])           (switch serves at cap)
  goodput_sf  = rc * min over the sub-flow's hops of scale
  q[l]       += (offered[l] - cap[l])+ * dt          (congestion signal)
  ECN mark    : RED ramp on q;   DCQCN reacts per sub-flow
  SeqBalance  : fabric marks are mirrored to the source ToR as Congestion
                Packets -> CongestionTable inactive for phi; NEW sub-flows
                double-hash around inactive paths; placed sub-flows never
                move (=> no reordering by construction).
  DRILL       : per-packet spray -> per-step inverse-queue weights over all
                paths; pays the go-back-N goodput penalty (core/gbn.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, congestion_table as ctab, hashing, routing, shaper
from repro.netsim import dataplane, dcqcn as dcqcn_mod
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace

SCHEMES = ("seqbalance", "ecmp", "letflow", "conga", "drill", "flowlet_timeout")

# A sub-flow is complete when its remaining bytes drop below this.  The
# ``rc <= remaining*8/dt`` cap makes the last bytes decay geometrically, so
# an exact-zero test would tail for ~8 steps on f32 underflow — and WHICH
# step it underflows on is 1-ulp sensitive to summation order, which would
# make dense vs active-window finish times diverge.  An eighth of a byte is
# far below one packet, so cutting there changes nothing physical, and even
# MAX_SUBFLOWS-many sub-flow residues stay under one byte per WQE.
DONE_EPS_BYTES = 0.125


@dataclasses.dataclass(frozen=True)
class SimConfig:
    scheme: str = "seqbalance"
    n_sub: int = 4  # N (SeqBalance Shaper); forced to 1 for other schemes
    min_split_bytes: float = 16e3  # Shaper floor: WQEs below this stay whole
    phi: float = 32e-6
    flowlet_timeout: float = 100e-6
    dt: float = 10e-6
    duration_s: float = 20e-3
    dcqcn: dcqcn_mod.DCQCNParams = dcqcn_mod.DCQCNParams()
    gbn_window_pkts: float = 16.0
    drill_jitter_mtus: float = 4.0
    drill_q0: float = 1500.0
    mark_salt: int = 0xA5A5
    qmax_bytes: float = 8e6
    # a path is declared congested when at least this many ECN-marked
    # packets are mirrored back to the source ToR within one step (the
    # expected-marks intensity; deterministic, avoids mark-noise herding)
    cong_threshold_pkts: float = 1.0
    # dataplane backend: "auto" (Pallas on TPU, XLA elsewhere), "xla",
    # "pallas", or "pallas_interpret" (tests) — see netsim/dataplane.py
    dataplane: str = "auto"
    # compact engine (netsim/compact.py) only: the per-step while_loop runs
    # in lax.scan chunks of this many steps (early exit checked per chunk)
    chunk_steps: int = 32
    # compact engine only: window-average the [T, L, S] uplink trace over
    # this many steps inside the scan — sweeps that only need sampled
    # imbalance stats (metrics.throughput_imbalance's sample_every) stop
    # materializing the full per-step trace.  1 = keep every step (exact
    # dense-engine layout).
    uplink_sample_every: int = 1
    # compact engine only: event-driven adaptive dt (DESIGN.md §15).  When
    # True, each chunk boundary evaluates a quiescence predicate (no
    # arrival / finish / capacity edge / ECN crossing possible inside the
    # macro-step, DCQCN pinned at line rate) and a lax.cond fast-forwards
    # the whole macro-step in closed form instead of scanning it.  False
    # keeps the step loop bit-identical to the fixed-dt engine.
    adaptive: bool = False
    # macro-step cap, in scan chunks: the fast-forward span is
    # ff_macro_chunks * chunk_steps worth of dt steps (chunk boundaries are
    # the event grid, so spans stay chunk-aligned).  1 = one chunk.
    ff_macro_chunks: int = 1
    # quiescence margins: queues must stay below ff_kmin_frac * kmin for
    # the whole span (conservative headroom under the ECN ramp), and no
    # active sub-flow may finish within span + ff_margin_steps steps.
    ff_kmin_frac: float = 0.9
    ff_margin_steps: int = 2

    def __post_init__(self):
        assert self.scheme in SCHEMES, self.scheme
        assert self.dataplane in ("auto", "xla", "pallas", "pallas_interpret")
        assert self.chunk_steps >= 1 and self.uplink_sample_every >= 1
        assert self.ff_macro_chunks >= 1 and self.ff_margin_steps >= 0
        assert 0.0 < self.ff_kmin_frac <= 1.0
        if self.scheme != "seqbalance":
            object.__setattr__(self, "n_sub", 1)


class SimState(NamedTuple):
    remaining: jax.Array  # f32[F, N] bytes
    path: jax.Array  # i32[F, N]
    assigned: jax.Array  # bool[F]
    sub_done: jax.Array  # bool[F, N]
    finish: jax.Array  # f32[F] (+inf until CQE)
    cc: dcqcn_mod.DCQCNState  # [F, N]
    table: ctab.CongestionTable  # [n_leaf, n_paths]
    queue: jax.Array  # f32[n_links+1]
    cqe: shaper.CQEState  # [F]
    cnp_pkts: jax.Array  # f32 scalar — Congestion Packet counter (Table II)
    step: jax.Array  # i32


class StepOutputs(NamedTuple):
    uplink_load: jax.Array  # f32[n_leaf, n_uplinks] offered bps
    goodput_total: jax.Array  # f32 scalar bps (sum of delivered)
    cnp_rate: jax.Array  # f32 congestion packets this step
    max_queue: jax.Array  # f32 bytes


def _u32(x):
    return jnp.asarray(x, jnp.uint32)


class FlowConsts(NamedTuple):
    """Per-flow constants derived once from the trace (shared by the dense
    oracle here and the active-window engine in netsim/compact.py)."""

    sub_sizes: jax.Array  # f32[F, N] Shaper split (min_split floor applied)
    s5: tuple  # 4 x u32[F, N] per-sub-flow five-tuples (SeqBalance QPs)
    f5: tuple  # 4 x u32[F] per-flow five-tuple (other schemes)
    sub_salt: jax.Array  # u32[F, N] DCQCN mark-draw salt
    src_leaf: jax.Array  # i32[F]
    dst_leaf: jax.Array  # i32[F]


def flow_constants(topo: Topology, cfg: SimConfig, sizes, src, dst, fid) -> FlowConsts:
    F = sizes.shape[0]
    N = cfg.n_sub
    sub_sizes = shaper.split_wqe(sizes, N)  # f32[F, N]
    if N > 1:
        # The Shaper only segments WQEs worth segmenting: below the floor a
        # message rides a single QP (sub-WQE 0); its sibling slots carry
        # zero bytes and are born completed (their CQE bits set trivially).
        whole = jnp.concatenate(
            [sizes[:, None], jnp.zeros((F, N - 1), sizes.dtype)], axis=1
        )
        split_mask = (sizes >= cfg.min_split_bytes)[:, None]
        sub_sizes = jnp.where(split_mask, sub_sizes, whole)
    # five-tuples: SeqBalance -> per-sub-flow QPs; others -> per-flow
    s5 = shaper.subflow_five_tuples(src, dst, fid, N)  # each [F, N]
    f5 = (_u32(src), _u32(dst), _u32(0xB000) + (hashing.fmix32(fid) % _u32(0x3FFF)),
          jnp.full((F,), 4791, jnp.uint32))
    sub_salt = hashing.fmix32(s5[2] ^ (_u32(fid)[:, None] * _u32(2246822519)))  # [F,N]
    hpl = topo.hosts_per_leaf
    return FlowConsts(sub_sizes, s5, f5, sub_salt, src // hpl, dst // hpl)


def line_rate_of(topo: Topology) -> jax.Array:
    return topo.capacity[topo.n_links - 2 * topo.n_hosts]  # host_tx[0] bw


def build_sim(topo: Topology, cfg: SimConfig, trace: Trace, reorder=None):
    """Returns (init_state, step_fn, static) for the given scheme/topo/trace.

    ``reorder`` (traced f32 scalar or None) switches on the flowcell
    reordering-cost model: delivered throughput divides by
    ``dataplane.reorder_gbn_factor`` wherever the trace's ``spray`` column
    says a flow's parent chunk straddles >1 path.  ``None`` compiles the
    exact pre-flowcell program (the Python-level gate, same convention as
    the compact engine's ``loss``)."""
    F = len(trace.sizes)
    N = cfg.n_sub
    P = topo.n_paths

    sizes = jnp.asarray(trace.sizes)
    arrivals = jnp.asarray(trace.arrivals)
    src = jnp.asarray(trace.src)
    dst = jnp.asarray(trace.dst)
    fid = jnp.asarray(trace.flow_id)
    valid = jnp.asarray(trace.valid)
    spray = jnp.asarray(trace.spray)

    fc = flow_constants(topo, cfg, sizes, src, dst, fid)
    sub_sizes, s5, f5, sub_salt = fc.sub_sizes, fc.s5, fc.f5, fc.sub_salt
    src_leaf, dst_leaf = fc.src_leaf, fc.dst_leaf
    line_rate = line_rate_of(topo)
    qmask = dataplane.queue_mask_for(topo)

    if cfg.scheme in ("conga", "drill", "flowlet_timeout"):
        assert topo.kind == "leaf_spine", f"{cfg.scheme} is 2-tier only (paper §IV.B)"
    if reorder is not None:
        assert topo.kind == "leaf_spine", "reorder cost model is 2-tier only"
    if cfg.scheme == "flowlet_timeout":
        # WCMP re-draw weights: the per-leaf uplink capacities (the
        # asymmetric-topology flowlet controller — fat uplinks absorb
        # proportionally more flowlets; uniform capacities -> LetFlow).
        cap_up = topo.capacity[: topo.n_leaf * P].reshape(topo.n_leaf, P)
        up_w = baselines.wcmp_weights(cap_up)  # [L, P]

    nl = topo.n_links
    tx_link, rx_link = topo.nic_links(src, dst)  # i32[F] — path-independent

    def init_state() -> SimState:
        return SimState(
            remaining=sub_sizes,
            path=jnp.full((F, N), -1, jnp.int32),
            assigned=jnp.zeros((F,), bool),
            sub_done=sub_sizes <= 0.0,
            finish=jnp.full((F,), jnp.inf, jnp.float32),
            cc=dcqcn_mod.init_state((F, N), line_rate),
            table=ctab.CongestionTable.create(topo.n_leaf, P),
            queue=jnp.zeros((nl + 1,), jnp.float32),
            cqe=shaper.CQEState.create(F, N),
            cnp_pkts=jnp.zeros((), jnp.float32),
            step=jnp.zeros((), jnp.int32),
        )

    dparams = cfg.dcqcn

    def step_fn(state: SimState, _=None):
        t = state.step.astype(jnp.float32) * cfg.dt
        arrived = valid & (t >= arrivals)
        newly = arrived & ~state.assigned
        active_flow = state.assigned & jnp.isinf(state.finish)

        # ---------------- path (re)assignment ----------------
        path = state.path
        if cfg.scheme == "seqbalance":
            inact = ctab.inactive_matrix(state.table, t)  # [L, P]
            # Congestion that is GLOBAL carries no routing signal: if more
            # than half of a ToR's paths are marked, avoiding the marked
            # ones just herds arrivals onto the remainder.  Treat the table
            # as stale in that case and fall back to the plain hash (the
            # paper's table is only ever differential: "the stored
            # information pertains only to paths experiencing congestion").
            stale = inact.sum(-1, keepdims=True) > (P // 2)
            inact = jnp.where(stale, False, inact)
            rows = inact[src_leaf][:, None, :]  # [F,1,P]
            rows = jnp.broadcast_to(rows, (F, N, P))
            p_new = routing.select_paths(*s5, rows, P)  # [F,N]
            path = jnp.where(newly[:, None], p_new, path)
        elif cfg.scheme == "ecmp":
            p_new = routing.ecmp_paths(*f5, P)[:, None]
            path = jnp.where(newly[:, None], p_new, path)
        elif cfg.scheme in ("letflow", "conga", "flowlet_timeout"):
            rng = hashing.fmix32(fid ^ _u32(state.step) * _u32(0x85EBCA77))
            p_init = routing.ecmp_paths(*f5, P)
            gap = baselines.flowlet_gap_occurs(
                state.cc.rc[:, 0], dparams.mtu_bytes, cfg.flowlet_timeout
            )
            if cfg.scheme == "letflow":
                p_re = baselines.letflow_paths(path[:, 0], gap, rng, P)
            elif cfg.scheme == "flowlet_timeout":
                p_re = baselines.flowlet_wcmp_paths(path[:, 0], gap, rng, up_w[src_leaf])
            else:
                # CONGA reroutes to the least-congested path, but only at a
                # flowlet boundary; initial placement stays hash-based (the
                # fluid model would otherwise herd every same-step arrival
                # onto one path, which the real per-flowlet DRE feedback
                # does not do).
                pq = dataplane.path_queue_2tier(topo, state.queue, src_leaf, dst_leaf)
                p_re = baselines.conga_paths(path[:, 0], gap, pq)
            p_next = jnp.where(newly, p_init, jnp.where(active_flow, p_re, path[:, 0]))
            path = p_next[:, None]
        else:  # drill: nominal path 0; real split via weights below
            path = jnp.where(newly[:, None], 0, path)
        assigned = state.assigned | newly

        active = assigned[:, None] & ~state.sub_done & jnp.isinf(state.finish)[:, None]
        # a sub-flow can never offer more than the bytes it still has to send
        # (a 4 KB message is a 0.3 us burst at 100G, not a full dt of line rate)
        rc = jnp.where(
            active, jnp.minimum(state.cc.rc, state.remaining * 8.0 / cfg.dt), 0.0
        )  # [F,N]

        # -------- offered load, cascaded hop-by-hop (NIC serializes first,
        # then fabric: a hop's arrivals are the UPSTREAM-scaled rates, so a
        # host can never inject more than its NIC line rate into the fabric).
        # The pipeline lives in netsim/dataplane.py, shared with the
        # active-window engine and the linkload_cascade Pallas kernels; the
        # NIC-tiered form pre-reduces the N sub-flows sharing a host NIC.
        if cfg.scheme == "drill":
            arrival, thr, w, pq = dataplane.drill_spray(
                topo, state.queue, rc[:, 0], src, dst, src_leaf, dst_leaf,
                active[:, 0:1], cfg.drill_q0,
            )
            new_queue, p_mark = dataplane.integrate_queue(
                state.queue, arrival, topo.capacity, qmask, dparams,
                dt=cfg.dt, qmax_bytes=cfg.qmax_bytes, n_links=nl,
            )
            p_sub, p_sub_fabric = dataplane.drill_mark_probs(
                topo, p_mark, w, src_leaf, dst_leaf, dst
            )
            thr = thr * dataplane.drill_gbn_factor(
                topo, pq, w, rc[:, 0], mtu_bytes=dparams.mtu_bytes,
                jitter_mtus=cfg.drill_jitter_mtus, window_pkts=cfg.gbn_window_pkts,
            )
            thr = thr[:, None]  # [F,1]
        else:
            fab = topo.fabric_links(src_leaf[:, None], dst_leaf[:, None], path)
            arrival, new_queue, p_mark, thr = dataplane.cascade_nic(
                fab, tx_link, rx_link, rc, state.queue, topo.capacity, qmask,
                n_links=nl, kmin=dparams.kmin_bytes, kmax=dparams.kmax_bytes,
                pmax=dparams.pmax, dt=cfg.dt, qmax_bytes=cfg.qmax_bytes,
                backend=cfg.dataplane,
            )
            p_sub, p_sub_fabric = dataplane.subflow_mark_probs_nic(
                fab, tx_link, rx_link, p_mark, nl
            )
            if reorder is not None:
                pq = dataplane.path_queue_2tier(topo, state.queue, src_leaf, dst_leaf)
                amp = dataplane.reorder_gbn_factor(
                    topo, pq, spray, rc[:, 0], reorder,
                    mtu_bytes=dparams.mtu_bytes,
                    jitter_mtus=cfg.drill_jitter_mtus,
                    window_pkts=cfg.gbn_window_pkts,
                )
                thr = thr / amp[:, None]

        # ---------------- transfer progress & CQE ----------------
        delivered = thr * cfg.dt / 8.0  # bytes
        new_remaining = jnp.maximum(state.remaining - jnp.where(active, delivered, 0.0), 0.0)
        sub_done = assigned[:, None] & (new_remaining <= DONE_EPS_BYTES)
        cqe = shaper.ack_mask(state.cqe, sub_done)
        all_done = shaper.cqe_ready(cqe) & assigned & valid
        finish = jnp.where(jnp.isinf(state.finish) & all_done, t + cfg.dt, state.finish)

        # ---------------- DCQCN ----------------
        flow_salt = sub_salt if cfg.scheme == "seqbalance" else sub_salt[:, :1]
        flow_salt = jnp.broadcast_to(flow_salt, (F, N))
        cc, _ = dcqcn_mod.step(
            state.cc, p_sub, active, cfg.dt, line_rate, dparams, state.step, flow_salt
        )

        # ---------------- SeqBalance Congestion Packets ----------------
        table = state.table
        pkts = jnp.where(active, rc * cfg.dt / (8.0 * dparams.mtu_bytes), 0.0)
        exp_cong_pkts = jnp.sum(pkts * p_sub_fabric)  # mirrored-packet count
        if cfg.scheme == "seqbalance":
            # expected number of marked data packets per (source ToR, path)
            # this step = expected Congestion Packets mirrored back; the
            # source ToR marks the path inactive when at least one arrives.
            intensity = jnp.zeros((topo.n_leaf, P), jnp.float32)
            idx_leaf = jnp.broadcast_to(src_leaf[:, None], (F, N)).reshape(-1)
            idx_path = jnp.clip(path, 0, P - 1).reshape(-1)
            intensity = intensity.at[idx_leaf, idx_path].add(
                (pkts * p_sub_fabric).reshape(-1)
            )
            dense = intensity >= cfg.cong_threshold_pkts
            table = ctab.mark_congested_dense(table, dense, t, cfg.phi)

        new_state = SimState(
            remaining=new_remaining,
            path=path,
            assigned=assigned,
            sub_done=sub_done,
            finish=finish,
            cc=cc,
            table=table,
            queue=new_queue,
            cqe=cqe,
            cnp_pkts=state.cnp_pkts + exp_cong_pkts,
            step=state.step + 1,
        )
        out = StepOutputs(
            uplink_load=arrival[jnp.asarray(topo.uplink_ids)],
            goodput_total=jnp.sum(jnp.where(active, thr, 0.0)),
            cnp_rate=exp_cong_pkts,
            max_queue=jnp.max(new_queue[:nl]),
        )
        return new_state, out

    return init_state, step_fn


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run(topo: Topology, cfg: SimConfig, trace_arrays):
    trace = Trace(*trace_arrays)
    init_state, step_fn = build_sim(topo, cfg, trace)
    n_steps = int(round(cfg.duration_s / cfg.dt))
    final, outs = jax.lax.scan(step_fn, init_state(), None, length=n_steps)
    return final, outs


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run_reorder(topo: Topology, cfg: SimConfig, trace_arrays, reorder):
    trace = Trace(*trace_arrays)
    init_state, step_fn = build_sim(topo, cfg, trace, reorder=reorder)
    n_steps = int(round(cfg.duration_s / cfg.dt))
    final, outs = jax.lax.scan(step_fn, init_state(), None, length=n_steps)
    return final, outs


def simulate(
    topo: Topology, cfg: SimConfig, trace: Trace, reorder=None
) -> tuple[SimState, StepOutputs]:
    """Run the fluid simulation; returns (final_state, per-step outputs).

    ``reorder`` (float packets or None) enables the flowcell reordering
    cost as a TRACED budget: one compiled program per (topo, cfg) covers
    every budget value.  ``None`` dispatches the pre-flowcell program."""
    arrays = (trace.sizes, trace.arrivals, trace.src, trace.dst,
              trace.flow_id, trace.valid, trace.spray)
    arrays = tuple(jnp.asarray(a) for a in arrays)
    if reorder is None:
        return _run(topo, cfg, arrays)
    return _run_reorder(topo, cfg, arrays, jnp.float32(reorder))
