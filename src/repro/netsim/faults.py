"""Chaos campaign framework: heterogeneous fault schedules for the co-sim.

The co-sim's original fault vocabulary (``dist.cosim.FaultEvent``) covers
clean capacity faults at EPOCH granularity — a spine dies at epoch k and
recovers at epoch k+m.  The failure modes that actually dominate RDMA
deployments (Eunomia, arXiv 2412.08540; the hyperscale issues survey,
arXiv 2302.03337) are messier: ports that FLAP on and off at sub-epoch
timescales, links that stay up but drop packets (each loss costing a
go-back-N window rewind, the paper's Table-1 amplification), PFC pause
storms freezing a link for a burst, and hosts that straggle without any
link fault at all.  This module compiles a seeded mix of those into the
operands the sweep runner already knows how to trace:

  * ``capacity_schedule(topo, epoch)`` -> f32[K, n_links+1] — a WALL-CLOCK
    capacity schedule: the horizon is cut into ``n_segments`` equal step
    windows and each active flap/pause/brown-out multiplies its links'
    capacity in the segments it covers.  K is FIXED for the whole campaign
    (healthy epochs repeat the base row), so the compiled program's shapes
    never change and every epoch reuses ONE executable — the PR-5
    traced-capacity contract extended from a vector to a schedule.
  * ``loss_at(topo, epoch)`` -> f32[n_links+1] — per-link packet-loss
    rates driving ``core.gbn.gbn_goodput_factor`` inside the dataplane:
    offered load stays at the DCQCN rate (the wire carries the
    retransmissions) while goodput deflates by 1/(1 + p*W/2), so lossy
    flows occupy the fabric LONGER at full rate — offered load integrated
    over the transfer inflates by exactly the GBN waste.  Always returned
    (zeros when no lossy event is active) so the sweep operand arity —
    and therefore the compiled program — stays constant across epochs.
  * ``straggler_slowdowns(epoch)`` -> {rank: slowdown} — cadence
    stretches for ``dist.elastic.StragglerPolicy`` to chew on.
  * ``midepoch_onset(topo, epoch)`` — the earliest intra-epoch fault
    onset this epoch plus the paths it kills, the trigger for the co-sim
    driver's in-epoch replanning (``dist.cosim``).

``random_campaign`` draws a reproducible mixed campaign from a seed — the
chaos-smoke entry point for CI and the benches.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


def _check_span(start_epoch: int, end_epoch: int | None) -> None:
    assert start_epoch >= 0, start_epoch
    if end_epoch is not None:
        assert end_epoch > start_epoch, (start_epoch, end_epoch)


def _active(start_epoch: int, end_epoch: int | None, epoch: int) -> bool:
    return start_epoch <= epoch and (end_epoch is None or epoch < end_epoch)


# ------------------------------------------------------------ event types
@dataclasses.dataclass(frozen=True)
class LinkFlap:
    """Links oscillate between healthy and ``scale`` x capacity while the
    event is active: the flap cycle is ``period_frac`` of an epoch, down
    for ``duty`` of each cycle.  ``duty=1.0`` degenerates to a steady
    fault, which combined with ``onset_frac > 0`` models a MID-EPOCH kill
    — the case that forces in-epoch replanning rather than waiting for
    the next planning round.  ``onset_frac`` only applies in the start
    epoch; later active epochs flap from their first segment."""

    links: tuple[int, ...]
    start_epoch: int
    end_epoch: int | None = None
    period_frac: float = 0.25
    duty: float = 0.5
    onset_frac: float = 0.0
    scale: float = 0.0

    def __post_init__(self):
        assert len(self.links) > 0, "flap with no links is a no-op typo"
        _check_span(self.start_epoch, self.end_epoch)
        assert 0.0 < self.period_frac <= 1.0, self.period_frac
        assert 0.0 < self.duty <= 1.0, self.duty
        assert 0.0 <= self.onset_frac < 1.0, self.onset_frac
        assert 0.0 <= self.scale < 1.0, self.scale

    def active(self, epoch: int) -> bool:
        return _active(self.start_epoch, self.end_epoch, epoch)


@dataclasses.dataclass(frozen=True)
class Brownout:
    """Steady capacity degradation at epoch granularity — the campaign
    spelling of ``dist.cosim.FaultEvent`` (which the campaign also accepts
    directly: anything with ``links`` / ``scale`` / ``active(epoch)``)."""

    links: tuple[int, ...]
    scale: float
    start_epoch: int
    end_epoch: int | None = None

    def __post_init__(self):
        assert len(self.links) > 0, "brownout with no links is a no-op typo"
        _check_span(self.start_epoch, self.end_epoch)
        assert 0.0 <= self.scale < 1.0, self.scale

    def active(self, epoch: int) -> bool:
        return _active(self.start_epoch, self.end_epoch, epoch)


@dataclasses.dataclass(frozen=True)
class PauseWindow:
    """PFC-style pause: the links transmit NOTHING for the
    [onset_frac, onset_frac + width_frac) slice of each active epoch —
    capacity pinned to zero for those segments, everything queues behind
    it.  Transient by construction, so it does NOT trigger in-epoch
    replanning (the link is healthy again before a replan could land);
    sustained storms show up through the congestion reports instead."""

    links: tuple[int, ...]
    start_epoch: int
    end_epoch: int | None = None
    onset_frac: float = 0.25
    width_frac: float = 0.25

    def __post_init__(self):
        assert len(self.links) > 0, "pause with no links is a no-op typo"
        _check_span(self.start_epoch, self.end_epoch)
        assert 0.0 <= self.onset_frac < 1.0, self.onset_frac
        assert 0.0 < self.width_frac <= 1.0, self.width_frac

    def active(self, epoch: int) -> bool:
        return _active(self.start_epoch, self.end_epoch, epoch)


@dataclasses.dataclass(frozen=True)
class LossyLink:
    """Links stay up at full capacity but drop ``loss_rate`` of packets —
    the silent-drop failure mode (optics degradation, shallow-buffer tail
    drops) that go-back-N turns into the paper's Table-1 FCT blowup.  The
    dataplane multiplies goodput by ``gbn_goodput_factor(p_loss, W)``
    while the offered rate keeps riding the wire, so the damage is
    congestion-visible, not just per-flow."""

    links: tuple[int, ...]
    loss_rate: float
    start_epoch: int
    end_epoch: int | None = None

    def __post_init__(self):
        assert len(self.links) > 0, "lossy event with no links is a no-op typo"
        _check_span(self.start_epoch, self.end_epoch)
        assert 0.0 < self.loss_rate <= 1.0, self.loss_rate

    def active(self, epoch: int) -> bool:
        return _active(self.start_epoch, self.end_epoch, epoch)


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Ring member ``rank`` takes ``slowdown`` x the healthy step time
    while active — no link fault at all, just a slow host (thermal
    throttling, a noisy neighbor).  The co-sim driver feeds the stretched
    step durations into ``dist.elastic.StragglerPolicy``; until the rank
    is quarantined it gates the bulk-synchronous cadence for everyone."""

    rank: int
    slowdown: float
    start_epoch: int
    end_epoch: int | None = None

    def __post_init__(self):
        assert self.rank >= 0, self.rank
        _check_span(self.start_epoch, self.end_epoch)
        assert self.slowdown > 1.0, self.slowdown

    def active(self, epoch: int) -> bool:
        return _active(self.start_epoch, self.end_epoch, epoch)


class Onset(NamedTuple):
    """A mid-epoch fault onset: when (fraction of the epoch horizon) and
    which paths it takes down — the in-epoch replanning trigger."""

    frac: float
    paths: tuple[int, ...]


def _event_key(ev) -> tuple:
    """Campaign-dedup identity: (kind, links-or-rank, epoch window).  Two
    events sharing a key hit the same links over the same span — whatever
    their magnitudes, composing them double-counts one physical fault
    (two identical brownouts multiply into a quadratic one)."""
    where = tuple(ev.links) if hasattr(ev, "links") else ("rank", ev.rank)
    return (type(ev).__name__, where, ev.start_epoch, ev.end_epoch)


# --------------------------------------------------------------- campaign
def _flap_down_segments(ev: LinkFlap, epoch: int, K: int) -> np.ndarray:
    """bool[K]: segments in which ``ev``'s links are down this epoch."""
    down = np.zeros(K, bool)
    if not ev.active(epoch):
        return down
    start = int(ev.onset_frac * K) if epoch == ev.start_epoch else 0
    cycle = max(1, int(round(ev.period_frac * K)))
    n_down = max(1, int(round(ev.duty * cycle)))
    for k in range(start, K):
        if ((k - start) % cycle) < n_down:
            down[k] = True
    return down


@dataclasses.dataclass(frozen=True)
class FaultCampaign:
    """A fixed mix of fault events compiled per epoch into the sweep's
    traced operands.  ``n_segments`` is the wall-clock resolution of the
    capacity schedule — constant across the campaign so every epoch (and
    every cell of a campaign grid on the same topology) shares one
    compiled program."""

    events: tuple
    n_segments: int = 8

    def __post_init__(self):
        assert self.n_segments >= 1, self.n_segments
        seen: set[tuple] = set()
        for ev in self.events:
            assert hasattr(ev, "active"), ev
            key = _event_key(ev)
            # a duplicate-seed campaign (same kind, links, window twice)
            # silently double-counts one physical fault — two stacked 0.5x
            # brownouts are a 0.25x one nobody asked for.  Reject at
            # construction; composing DIFFERENT windows/links is fine.
            assert key not in seen, \
                f"duplicate campaign event (kind, links, window): {key}"
            seen.add(key)

    def seg_steps(self, n_steps: int, align: int = 1) -> int:
        """Steps per capacity-schedule segment (the static stride the
        compact engine indexes the schedule with).  ``align`` rounds the
        stride UP to a multiple of the engine's scan-chunk length: with
        adaptive dt the chunk grid IS the event grid, and an aligned
        stride means no chunk ever straddles a capacity segment edge —
        the quiescence predicate's capacity check then never blocks a
        fast-forward mid-segment.  ``align=1`` (default) keeps the PR 6
        uniform stride bit-identical."""
        base = max(1, -(-int(n_steps) // self.n_segments))
        a = max(int(align), 1)
        return -(-base // a) * a

    def capacity_schedule(self, topo, epoch: int) -> np.ndarray:
        """f32[n_segments, n_links + 1] — this epoch's wall-clock capacity
        schedule (row k covers steps [k*seg, (k+1)*seg))."""
        K = self.n_segments
        cap = np.repeat(
            np.asarray(topo.capacity, np.float32)[None, :], K, axis=0)
        for ev in self.events:
            if isinstance(ev, (LossyLink, Straggler)):
                continue
            links = list(ev.links)
            if isinstance(ev, LinkFlap):
                down = _flap_down_segments(ev, epoch, K)
                if down.any():
                    cap[np.ix_(down, links)] *= np.float32(ev.scale)
            elif isinstance(ev, PauseWindow):
                if ev.active(epoch):
                    k0 = int(ev.onset_frac * K)
                    k1 = int(round((ev.onset_frac + ev.width_frac) * K))
                    cap[k0:max(k1, k0 + 1), links] = 0.0
            elif ev.active(epoch):  # Brownout / cosim.FaultEvent duck-type
                cap[:, links] *= np.float32(ev.scale)
        return cap

    def loss_at(self, topo, epoch: int) -> np.ndarray:
        """f32[n_links + 1] per-link packet-loss rates this epoch.  Always
        returned (zeros when clean) so the traced-operand arity — and the
        compiled program — never changes mid-campaign."""
        loss = np.zeros(topo.n_links + 1, np.float32)
        for ev in self.events:
            if isinstance(ev, LossyLink) and ev.active(epoch):
                links = list(ev.links)
                loss[links] = np.maximum(loss[links], np.float32(ev.loss_rate))
        return loss

    def has_loss(self) -> bool:
        return any(isinstance(ev, LossyLink) for ev in self.events)

    def straggler_slowdowns(self, epoch: int) -> dict[int, float]:
        out: dict[int, float] = {}
        for ev in self.events:
            if isinstance(ev, Straggler) and ev.active(epoch):
                out[ev.rank] = max(out.get(ev.rank, 1.0), ev.slowdown)
        return out

    def has_stragglers(self) -> bool:
        return any(isinstance(ev, Straggler) for ev in self.events)

    def midepoch_onset(self, topo, epoch: int) -> Onset | None:
        """The earliest intra-epoch capacity-fault onset starting THIS
        epoch, with the fabric paths its links take down — None when no
        flap begins mid-epoch (epoch-boundary faults are the planner's
        ordinary job; pause windows self-heal before a replan lands)."""
        from repro.netsim.topology import paths_for_link

        hits = [ev for ev in self.events
                if isinstance(ev, LinkFlap) and ev.start_epoch == epoch
                and ev.onset_frac > 0.0]
        if not hits:
            return None
        frac = min(ev.onset_frac for ev in hits)
        paths = sorted({p for ev in hits for link in ev.links
                        for p in paths_for_link(topo, link)})
        return Onset(frac=frac, paths=tuple(paths))

    def summary(self) -> list[str]:
        return [f"{type(ev).__name__} {ev}" for ev in self.events]

    def activations(self, epoch: int) -> list[dict]:
        """JSON-able descriptions of every event active THIS epoch — the
        flight log's ``faults`` field, so the perfetto exporter can lay
        each fault's span under the epochs it perturbs."""
        out = []
        for ev in self.events:
            if not ev.active(epoch):
                continue
            d = dict(kind=type(ev).__name__, start_epoch=ev.start_epoch,
                     end_epoch=ev.end_epoch)
            for f in ("links", "rank", "scale", "loss_rate", "slowdown",
                      "duty", "period_frac", "onset_frac", "width_frac"):
                if hasattr(ev, f):
                    v = getattr(ev, f)
                    d[f] = list(v) if isinstance(v, tuple) else v
            out.append(d)
        return out


def random_campaign(topo, *, seed: int, epochs: int, n_faults: int = 3,
                    kinds: tuple[str, ...] = ("flap", "brownout", "lossy",
                                              "pause", "straggler"),
                    n_ranks: int = 0, n_segments: int = 8) -> FaultCampaign:
    """Seeded heterogeneous campaign: ``n_faults`` events drawn uniformly
    over ``kinds``, each hitting a random fabric switch (``spine_links``)
    for a 2-3 epoch span inside [1, epochs).  ``n_ranks`` (the ring size)
    must be > 0 for the "straggler" kind to be drawable.  Deterministic in
    ``seed`` — the CI chaos smoke and the campaign bench replay the same
    schedule forever."""
    from repro.netsim.topology import spine_links

    assert epochs >= 3, epochs
    kinds = tuple(k for k in kinds if k != "straggler" or n_ranks > 0)
    assert kinds, "no drawable fault kinds"
    n_spines = topo.uplink_ids.shape[1]
    rng = np.random.default_rng(seed)
    events: list = []
    keys: set[tuple] = set()
    attempts = 0
    while len(events) < n_faults:
        # a colliding (kind, links, window) draw would be rejected by
        # FaultCampaign as a double-counted fault — redraw instead (the
        # no-collision path consumes the exact legacy RNG sequence, so
        # existing seeded campaigns replay unchanged)
        attempts += 1
        assert attempts <= 100 * n_faults, \
            "random_campaign cannot draw enough distinct faults"
        kind = str(rng.choice(kinds))
        start = int(rng.integers(1, max(epochs - 2, 2)))
        end = min(epochs, start + int(rng.integers(2, 4)))
        spine = int(rng.integers(n_spines))
        links = spine_links(topo, spine)
        if kind == "flap":
            ev = LinkFlap(
                links=links, start_epoch=start, end_epoch=end,
                period_frac=float(rng.uniform(0.25, 0.5)),
                duty=float(rng.uniform(0.3, 0.7)),
                onset_frac=float(rng.uniform(0.2, 0.6)))
        elif kind == "brownout":
            ev = Brownout(
                links=links, scale=float(rng.uniform(0.1, 0.5)),
                start_epoch=start, end_epoch=end)
        elif kind == "lossy":
            ev = LossyLink(
                links=links, loss_rate=float(rng.uniform(0.005, 0.05)),
                start_epoch=start, end_epoch=end)
        elif kind == "pause":
            ev = PauseWindow(
                links=links, start_epoch=start, end_epoch=end,
                onset_frac=float(rng.uniform(0.1, 0.5)),
                width_frac=float(rng.uniform(0.1, 0.3)))
        else:  # straggler
            ev = Straggler(
                rank=int(rng.integers(n_ranks)),
                slowdown=float(rng.uniform(2.0, 4.0)),
                start_epoch=start, end_epoch=end)
        key = _event_key(ev)
        if key in keys:
            continue
        keys.add(key)
        events.append(ev)
    return FaultCampaign(events=tuple(events), n_segments=n_segments)


# ------------------------------------------------------ telemetry channel
@dataclasses.dataclass
class TelemetryChannel:
    """The degraded CONTROL plane: a seeded model of the feedback path that
    carries congestion reports (and liveness heartbeats) from the fabric
    back to the planner.  The chaos campaign above makes the *data* plane
    hostile; this makes the *report* path hostile — at hyperscale the
    feedback channel is itself lossy and delayed (arXiv 2302.03337), and a
    no-reordering balancer that trusts stale or duplicated reports breaks
    its own invariant (arXiv 2412.08540).

    Per report: dropped with probability ``loss``; otherwise delivered
    ``delay_epochs`` (+ uniform extra in [0, jitter_epochs]) planning
    epochs after it was sent — jitter makes deliveries REORDER across
    epochs; with probability ``dup`` a second, independently delayed copy
    is delivered too.  ``reorder`` additionally shuffles the within-epoch
    delivery order (seeded).  ``blackout=(b0, b1)`` models a dead feedback
    path: any report SENT or DELIVERED inside [b0, b1) is lost — the
    scenario that must trip ``dist.elastic.TelemetryWatchdog``.

    Deterministic in ``seed`` and the send sequence; ``state``/``restore``
    round-trip the queue, the counters, and the RNG through the co-sim
    journal so a resumed campaign replays bit-identically.  A channel
    constructed with all-default degradation (loss=0, delay=0, jitter=0,
    dup=0, no blackout) delivers every report exactly once in order in its
    send epoch — bit-identical planner behavior to no channel at all (the
    property-tested perfect-channel contract)."""

    loss: float = 0.0
    delay_epochs: int = 0
    jitter_epochs: int = 0
    dup: float = 0.0
    reorder: bool = False
    seed: int = 0
    blackout: tuple[int, int] | None = None

    def __post_init__(self):
        assert 0.0 <= self.loss <= 1.0, self.loss
        assert 0.0 <= self.dup <= 1.0, self.dup
        assert self.delay_epochs >= 0 and self.jitter_epochs >= 0
        if self.blackout is not None:
            b0, b1 = self.blackout
            assert 0 <= b0 < b1, self.blackout
        self._rng = np.random.default_rng(self.seed)
        self._pending: dict[int, list[tuple[tuple, int]]] = {}
        self.sent = 0
        self.dropped = 0
        self.delivered = 0

    def config(self) -> dict:
        """JSON-stable identity of the channel's degradation parameters
        (the co-sim journal's spec key — a different channel is a
        different campaign)."""
        return dict(
            loss=float(self.loss), delay_epochs=int(self.delay_epochs),
            jitter_epochs=int(self.jitter_epochs), dup=float(self.dup),
            reorder=bool(self.reorder), seed=int(self.seed),
            blackout=None if self.blackout is None else
            [int(self.blackout[0]), int(self.blackout[1])],
        )

    def _blacked_out(self, epoch: int) -> bool:
        return self.blackout is not None \
            and self.blackout[0] <= epoch < self.blackout[1]

    def _arrival(self, epoch: int) -> int:
        extra = int(self._rng.integers(0, self.jitter_epochs + 1)) \
            if self.jitter_epochs else 0
        return epoch + self.delay_epochs + extra

    def send(self, payload: tuple, epoch: int) -> None:
        """Emit one epoch-stamped report.  ``payload`` is an opaque tuple
        (``dist.cosim`` sends ``("slow", path)`` and ``("hb", leaf)``)."""
        self.sent += 1
        # draw loss/dup/jitter unconditionally so the RNG stream — and
        # therefore every later report's fate — does not depend on whether
        # THIS epoch fell inside a blackout window
        lost = self.loss > 0.0 and float(self._rng.random()) < self.loss
        arrive = self._arrival(epoch)
        duped = self.dup > 0.0 and float(self._rng.random()) < self.dup
        arrive2 = self._arrival(epoch) + 1 if duped else -1
        if lost or self._blacked_out(epoch):
            self.dropped += 1
            return
        self._pending.setdefault(arrive, []).append((tuple(payload), epoch))
        if duped:
            self._pending.setdefault(arrive2, []).append(
                (tuple(payload), epoch))

    def deliver(self, epoch: int) -> list[tuple[tuple, int]]:
        """All (payload, origin_epoch) reports arriving by ``epoch`` that
        were not already collected — reports whose delivery epoch lands in
        a blackout window are lost in flight.  Call once per epoch, in
        epoch order."""
        batch: list[tuple[tuple, int]] = []
        for k in sorted(e for e in self._pending if e <= epoch):
            batch.extend(self._pending.pop(k))
        if self._blacked_out(epoch):
            self.dropped += len(batch)
            return []
        if self.reorder and len(batch) > 1:
            batch = [batch[i] for i in self._rng.permutation(len(batch))]
        self.delivered += len(batch)
        return batch

    def state(self) -> dict:
        """JSON-able snapshot (queue + counters + RNG) for the co-sim
        journal; ``restore`` makes a resumed run replay bit-identically."""
        return dict(
            pending={str(k): [[list(p), o] for p, o in v]
                     for k, v in self._pending.items()},
            sent=self.sent, dropped=self.dropped, delivered=self.delivered,
            rng=self._rng.bit_generator.state,
        )

    def restore(self, state: dict) -> None:
        self._pending = {
            int(k): [(tuple(p), int(o)) for p, o in v]
            for k, v in state.get("pending", {}).items()
        }
        self.sent = int(state.get("sent", 0))
        self.dropped = int(state.get("dropped", 0))
        self.delivered = int(state.get("delivered", 0))
        if state.get("rng"):
            self._rng.bit_generator.state = state["rng"]
