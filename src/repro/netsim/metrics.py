"""FCT / slowdown / imbalance metrics (paper §IV performance metrics)."""
from __future__ import annotations

import numpy as np

from repro.netsim.engine import SimState, StepOutputs
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace


def fct_stats(state: SimState, trace: Trace, topo: Topology, host_bw: float) -> dict:
    """FCT slowdown: actual FCT normalized to the FCT in an idle network
    (serialization at host line rate + one base RTT)."""
    finish = np.asarray(state.finish)
    arrivals = trace.arrivals
    done = np.isfinite(finish) & trace.valid
    if not done.any():
        return dict(n=0, avg_fct=np.nan, p99_fct=np.nan, avg_slowdown=np.nan,
                    p99_slowdown=np.nan, completion_rate=0.0)
    fct = finish[done] - arrivals[done]
    ideal = trace.sizes[done] * 8.0 / host_bw + topo.base_rtt_s
    slow = fct / ideal
    return dict(
        n=int(done.sum()),
        completion_rate=float(done.sum() / max(trace.valid.sum(), 1)),
        avg_fct=float(fct.mean()),
        p99_fct=float(np.percentile(fct, 99)),
        avg_slowdown=float(slow.mean()),
        p99_slowdown=float(np.percentile(slow, 99)),
    )


def throughput_imbalance(outs: StepOutputs, sample_every: int = 10, *,
                         trace_stride: int = 1) -> np.ndarray:
    """Paper's imbalance metric per ToR: (max uplink tput - min)/avg, sampled
    every ``sample_every`` steps (=100 us at dt=10 us).  Returns the flat
    sample population (for CDF plotting).  ToR/sample points with zero
    traffic are dropped.

    ``trace_stride`` is the window-averaging the engine already applied to
    ``outs.uplink_load`` (``SimConfig.uplink_sample_every``); the remaining
    averaging window here is ``sample_every // trace_stride``."""
    assert sample_every % max(trace_stride, 1) == 0, (
        f"engine stride {trace_stride} must divide sample_every "
        f"{sample_every} or the imbalance windows silently shift")
    up = np.asarray(outs.uplink_load)  # [T / trace_stride, L, S]
    k = max(1, sample_every // max(trace_stride, 1))
    T = (up.shape[0] // k) * k
    up = up[:T].reshape(-1, k, *up.shape[1:]).mean(axis=1)  # [T', L, S]
    avg = up.mean(axis=-1)
    imb = (up.max(axis=-1) - up.min(axis=-1)) / np.maximum(avg, 1e-9)
    return imb[avg > 1e6].ravel()


def fct_samples(state, trace: Trace,
                horizon_s: float | None = None) -> tuple[np.ndarray, float]:
    """Per-flow FCT population for CDFs / convergence curves.

    Unlike ``fct_stats`` (completed flows only), flows still unfinished at
    the end of the horizon are CENSORED at it (fct = horizon - arrival)
    rather than dropped: a killed spine starves its flows outright, and a
    p99 over survivors would report the disaster epoch as healthy.  Returns
    (fct[n_valid], completion_rate); with ``horizon_s=None`` unfinished
    flows keep +inf (caller beware of percentile poisoning).
    """
    finish = np.asarray(state.finish)
    valid = np.asarray(trace.valid, bool)
    arrivals = np.asarray(trace.arrivals)
    done = np.isfinite(finish) & valid
    completion = float(done.sum() / max(valid.sum(), 1))
    f = finish[valid]
    if horizon_s is not None:
        f = np.minimum(f, np.float32(horizon_s))
    return (f - arrivals[valid]).astype(np.float64), completion


def cdf(samples: np.ndarray, points: int = 50) -> tuple[np.ndarray, np.ndarray]:
    xs = np.sort(samples)
    ys = np.arange(1, len(xs) + 1) / len(xs)
    if len(xs) > points:
        idx = np.linspace(0, len(xs) - 1, points).astype(int)
        xs, ys = xs[idx], ys[idx]
    return xs, ys


def congestion_packet_bandwidth(state: SimState, duration_s: float,
                                pkt_bytes: float = 64.0) -> float:
    """Table II: bps consumed by mirrored Congestion Packets."""
    return float(state.cnp_pkts) * pkt_bytes * 8.0 / duration_s


def port_rate_timeseries(outs: StepOutputs, leaf: int, dt: float,
                         window_s: float = 1e-3, *,
                         trace_stride: int = 1) -> np.ndarray:
    """Per-uplink offered rate for one leaf, window-averaged (Fig. 10/11).
    ``trace_stride`` = window-averaging already applied by the engine."""
    steps = int(window_s / dt)
    assert steps % max(trace_stride, 1) == 0, (
        f"engine stride {trace_stride} must divide the {steps}-step window "
        f"or the rate windows silently shift")
    up = np.asarray(outs.uplink_load)[:, leaf, :]  # [T / trace_stride, S]
    k = max(1, steps // max(trace_stride, 1))
    T = (up.shape[0] // k) * k
    return up[:T].reshape(-1, k, up.shape[1]).mean(axis=1)
