"""FCT / slowdown / imbalance metrics (paper §IV performance metrics)."""
from __future__ import annotations

import numpy as np

from repro.netsim.engine import SimState, StepOutputs
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace


def fct_stats(state: SimState, trace: Trace, topo: Topology, host_bw: float) -> dict:
    """FCT slowdown: actual FCT normalized to the FCT in an idle network
    (serialization at host line rate + one base RTT)."""
    finish = np.asarray(state.finish)
    arrivals = trace.arrivals
    done = np.isfinite(finish) & trace.valid
    if not done.any():
        return dict(n=0, avg_fct=np.nan, p99_fct=np.nan, avg_slowdown=np.nan,
                    p99_slowdown=np.nan, completion_rate=0.0)
    fct = finish[done] - arrivals[done]
    ideal = trace.sizes[done] * 8.0 / host_bw + topo.base_rtt_s
    slow = fct / ideal
    return dict(
        n=int(done.sum()),
        completion_rate=float(done.sum() / max(trace.valid.sum(), 1)),
        avg_fct=float(fct.mean()),
        p99_fct=float(np.percentile(fct, 99)),
        avg_slowdown=float(slow.mean()),
        p99_slowdown=float(np.percentile(slow, 99)),
    )


def throughput_imbalance(outs: StepOutputs, sample_every: int = 10) -> np.ndarray:
    """Paper's imbalance metric per ToR: (max uplink tput - min)/avg, sampled
    every ``sample_every`` steps (=100 us at dt=10 us).  Returns the flat
    sample population (for CDF plotting).  ToR/sample points with zero
    traffic are dropped."""
    up = np.asarray(outs.uplink_load)  # [T, L, S]
    T = (up.shape[0] // sample_every) * sample_every
    up = up[:T].reshape(-1, sample_every, *up.shape[1:]).mean(axis=1)  # [T', L, S]
    avg = up.mean(axis=-1)
    imb = (up.max(axis=-1) - up.min(axis=-1)) / np.maximum(avg, 1e-9)
    return imb[avg > 1e6].ravel()


def cdf(samples: np.ndarray, points: int = 50) -> tuple[np.ndarray, np.ndarray]:
    xs = np.sort(samples)
    ys = np.arange(1, len(xs) + 1) / len(xs)
    if len(xs) > points:
        idx = np.linspace(0, len(xs) - 1, points).astype(int)
        xs, ys = xs[idx], ys[idx]
    return xs, ys


def congestion_packet_bandwidth(state: SimState, duration_s: float,
                                pkt_bytes: float = 64.0) -> float:
    """Table II: bps consumed by mirrored Congestion Packets."""
    return float(state.cnp_pkts) * pkt_bytes * 8.0 / duration_s


def port_rate_timeseries(outs: StepOutputs, leaf: int, dt: float,
                         window_s: float = 1e-3) -> np.ndarray:
    """Per-uplink offered rate for one leaf, window-averaged (Fig. 10/11)."""
    up = np.asarray(outs.uplink_load)[:, leaf, :]  # [T, S]
    k = max(1, int(window_s / dt))
    T = (up.shape[0] // k) * k
    return up[:T].reshape(-1, k, up.shape[1]).mean(axis=1)
