"""Per-phase timing of the compact-engine step (benchmarks/run.py --profile).

The compact step is composed of four phase closures (netsim/compact.py
``build_compact_sim`` returns them alongside ``step_fn``):

  admit   — searchsorted admission, slot resets, route-cache fill, routing
  cascade — offered rates -> NIC-tiered hop cascade -> queue/ECN marks
  dcqcn   — per-sub-flow rate control update
  finish  — transfer progress, bitmap CQE, scatter-on-finish, table update
  quiesce — adaptive-dt quiescence predicate (one chunk-boundary check)

``quiescence_profile`` additionally replays a fixed-dt run chunk by chunk
and records which chunk boundaries the adaptive engine would have
fast-forwarded — the quiescence occupancy (fraction of the horizon
coverable in closed form) and the macro-step length histogram.

Each phase is jitted and timed IN ISOLATION on a mid-simulation state (the
same state for every phase, reached by scanning ``warm_steps`` real steps),
so future perf PRs can attribute wins.  Phase times do not add up exactly
to the fused step (XLA fuses across phase boundaries and the isolated
phases pay their own dispatch), so the fused per-step time is reported
alongside as ``step_fused``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import compact
from repro.netsim.engine import SimConfig
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace


class TimeUs(float):
    """A per-call time in µs that is still a float (the value is the MIN
    over iterations — the least-noise estimator every phase table keys on)
    but carries the full per-iteration sample distribution for the flight
    log / bench JSON: ``.min_us`` / ``.mean_us`` / ``.std_us`` /
    ``.samples``, or all four via ``.stats()``."""

    __slots__ = ("samples",)

    def __new__(cls, samples):
        samples = [float(s) for s in samples]
        self = super().__new__(cls, min(samples))
        self.samples = samples
        return self

    @property
    def min_us(self) -> float:
        return float(self)

    @property
    def mean_us(self) -> float:
        return sum(self.samples) / len(self.samples)

    @property
    def std_us(self) -> float:
        m = self.mean_us
        return (sum((s - m) ** 2 for s in self.samples)
                / len(self.samples)) ** 0.5

    def stats(self) -> dict:
        """JSON-able {min_us, mean_us, std_us, iters}."""
        return dict(min_us=round(self.min_us, 3),
                    mean_us=round(self.mean_us, 3),
                    std_us=round(self.std_us, 3), iters=len(self.samples))


def _time_us(fn, *args, iters: int) -> TimeUs:
    jax.block_until_ready(fn(*args))  # compile + warm
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return TimeUs(samples)


def profile_phases(
    topo: Topology, cfg: SimConfig, trace: Trace, *,
    warm_steps: int = 200, iters: int = 30,
) -> dict[str, float]:
    """Time each compact-step phase on a warmed mid-sim state.  Returns
    {phase: µs} plus ``step_fused`` (the whole fused step) and
    ``phase_sum`` (sum of the isolated phases, for fusion-gap context)."""
    arrays, _, F = compact.sort_trace(trace)
    F_pad = max(F, 1)
    W, A = compact.plan_single_window(topo, cfg, arrays, F_pad)
    jarrays = tuple(jnp.asarray(a) for a in arrays)
    _, step_fn, phases = compact.build_compact_sim(topo, cfg, jarrays, W, F_pad, A)

    @jax.jit
    def warm(st):
        st2, _ = jax.lax.scan(step_fn, st, None, length=warm_steps)
        return st2

    st = jax.block_until_ready(warm(compact.init_compact_state(topo, cfg, W, F_pad)))
    t = st.step.astype(jnp.float32) * cfg.dt

    admit = jax.jit(phases["admit"])
    cascade = jax.jit(phases["cascade"])
    dcqcn = jax.jit(phases["dcqcn"])
    finish = jax.jit(phases["finish"])
    step = jax.jit(step_fn)
    K, _, _ = compact.plan_chunks(cfg, int(round(cfg.duration_s / cfg.dt)))
    quiesce = jax.jit(lambda s: phases["quiesce"](s, K))

    st_admit = jax.block_until_ready(admit(st))
    arrival, new_queue, thr, p_sub, p_fab, rc, active = cascade(st_admit)

    out = {
        "admit": _time_us(admit, st, iters=iters),
        "cascade": _time_us(cascade, st_admit, iters=iters),
        "dcqcn": _time_us(dcqcn, st_admit, p_sub, active, iters=iters),
        "finish": _time_us(
            finish, st_admit, t, thr, active, rc, p_fab, iters=iters),
        "step_fused": _time_us(step, st, iters=iters),
        "quiesce": _time_us(quiesce, st, iters=iters),
    }
    out["phase_sum"] = sum(out[k] for k in ("admit", "cascade", "dcqcn", "finish"))
    out["window_slots"] = W
    return out


def quiescence_profile(
    topo: Topology, cfg: SimConfig, trace: Trace, *, iters: int = 30,
) -> dict:
    """Quiescence occupancy of one sim: replay the fixed-dt trajectory in
    scan chunks, evaluating the adaptive engine's predicate at every chunk
    boundary (without fast-forwarding, so the trajectory stays the exact
    oracle).  Returns:

      ff_fraction   — fraction of the horizon whose chunks were quiescent
                      (what adaptive mode would cover in closed form)
      macro_hist    — {macro-step length in dt steps: count} from runs of
                      consecutive quiescent chunks
      predicate_us  — one predicate evaluation, jitted in isolation (the
                      per-chunk overhead adaptive mode pays on top of the
                      scan)
      chunk_steps / n_chunks — the event-grid geometry used
    """
    arrays, _, F = compact.sort_trace(trace)
    F_pad = max(F, 1)
    W, A = compact.plan_single_window(topo, cfg, arrays, F_pad)
    jarrays = tuple(jnp.asarray(a) for a in arrays)
    _, step_fn, phases = compact.build_compact_sim(topo, cfg, jarrays, W,
                                                   F_pad, A)
    n_steps = int(round(cfg.duration_s / cfg.dt))
    K, n_chunks, _ = compact.plan_chunks(cfg, n_steps)
    quiesce = phases["quiesce"]

    @jax.jit
    def replay(st):
        def one(st, _):
            quiet = quiesce(st, K)
            st2, _ = jax.lax.scan(step_fn, st, None, length=K)
            return st2, quiet

        return jax.lax.scan(one, st, None, length=n_chunks)[1]

    st0 = compact.init_compact_state(topo, cfg, W, F_pad)
    quiet = np.asarray(jax.block_until_ready(replay(st0)))
    hist: dict[int, int] = {}
    run = 0
    for q in list(quiet) + [False]:  # trailing False flushes the last run
        if q:
            run += 1
        elif run:
            hist[run * K] = hist.get(run * K, 0) + 1
            run = 0
    pred = jax.jit(lambda s: quiesce(s, K))
    return {
        "ff_fraction": float(quiet.mean()) if quiet.size else 0.0,
        "macro_hist": hist,
        "predicate_us": _time_us(pred, st0, iters=iters),
        "chunk_steps": K,
        "n_chunks": n_chunks,
    }
