"""Batched (seed, load) sweep runner over the active-window engine.

Paper-style evaluations run the same (scheme, topology) program over many
traces — workloads x loads x seeds (Fig. 12-14), and related work (RDMACell,
predictive LB) needs exactly this cheap batched what-if simulation.  Naively
that costs one XLA compile per trace shape plus one Python-dispatched scan
per sim.  This runner instead:

  * pads every trace to a shape bucket (``F`` to multiples of 2048, the
    active window ``W`` to multiples of 256, shared across the batch) so
    shapes — and therefore compilations — are reused;
  * runs each shape bucket through ONE compiled program — on cpu a B=1
    program executed per sim (own early exit + gated admission; see
    ``batch_mode``), on accelerators one jitted ``vmap`` over the stacked
    batch — with the +inf finish buffer donated (the one state buffer big
    enough to matter; the trace arrays are kept — the retry loop re-reads
    them);
  * memoizes compiled executables in a cache keyed on those statics
    (topology keyed by VALUE — kind/sizes/capacities — so two structurally
    identical Topology instances share one compilation);
  * when more than one local device is present, pads the batch to the
    device count and dispatches it as ONE pmap-of-vmap (one shard of the
    batch per device); the single-device path is untouched and stays
    bit-identical;
  * points JAX's persistent compilation cache at a scratch dir
    (``enable_compile_cache``): sweeps relaunch the same programs every
    process, so from the second process on the several-seconds-per-program
    XLA compiles are disk hits.

``run_batch`` is the workhorse; ``run_one`` is the single-trace
convenience wrapper used by benchmarks/common.run_sim.  ``run_jobs``
worker count comes from REPRO_SWEEP_WORKERS (default: capped cpu count).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import compact
from repro.netsim.engine import SimConfig, StepOutputs, line_rate_of
from repro.netsim.topology import Topology
from repro.netsim.workloads import Trace

F_BUCKET = 2048
W_BUCKET = 256

_JIT_CACHE: dict = {}
_CACHE_STATS = {"builds": 0, "hits": 0}
_OBS_STATS = {"spill_retries": 0, "job_retries": 0, "job_timeouts": 0,
              "job_failures": 0}
_COMPILE_CACHE_SET = False
_COMPILE_CACHE_LOCK = threading.Lock()
_JAX_TRACE_DIR: str | None = None


def cache_stats() -> dict:
    """Executable-cache counters: ``builds`` = programs constructed (one XLA
    compile each at first call), ``hits`` = dispatches served by an already
    built program.  The co-sim driver (``dist.cosim``) reads this per epoch
    to prove the compile-reuse-across-capacity-changes contract: with
    ``capacity`` passed as a traced operand, every epoch after the first
    must add zero builds."""
    return dict(_CACHE_STATS)


def obs_stats() -> dict:
    """Flight-log counters (DESIGN.md §16): the compile stats plus the
    sweep runner's resilience events — spill retries (``_run_group``
    window doubling), job retries / timeouts / salvaged failures
    (``run_jobs``).  The co-sim driver snapshots this per epoch into the
    flight log so a slow epoch is attributable (recompile? spill retry?
    crashed cell?) without rerunning anything."""
    out = dict(_CACHE_STATS)
    out.update(_OBS_STATS)
    return out


def enable_compile_cache() -> str | None:
    """Point JAX's persistent compilation cache at REPRO_COMPILE_CACHE
    (default: a per-user dir under $TMPDIR).  Paper sweeps re-launch the
    same (scheme, topology, shape) programs in every process — several
    seconds of XLA compile each — so the second process onward starts
    warm.  Set REPRO_COMPILE_CACHE=0 to disable.  Returns the dir in use
    (None when disabled).  Idempotent; called lazily by run_batch."""
    global _COMPILE_CACHE_SET
    try:  # never clobber a cache dir the user configured themselves
        configured = jax.config.jax_compilation_cache_dir
    except AttributeError:
        return None
    if configured:
        return configured
    path = os.environ.get("REPRO_COMPILE_CACHE")
    if path is None:
        import tempfile

        uid = os.getuid() if hasattr(os, "getuid") else "user"
        path = os.path.join(tempfile.gettempdir(), f"repro-xla-cache-{uid}")
    if path in ("", "0"):
        return None
    with _COMPILE_CACHE_LOCK:  # run_jobs calls this from worker threads
        if not _COMPILE_CACHE_SET:
            try:
                jax.config.update("jax_compilation_cache_dir", path)
                jax.config.update(
                    "jax_persistent_cache_min_compile_time_secs", 0.5)
                jax.config.update(
                    "jax_persistent_cache_min_entry_size_bytes", 0)
                # the cache module latches "no dir configured" on the first
                # compile of the process (e.g. a jnp op at import time) and
                # never re-reads the config — reset so the dir takes effect
                from jax._src import compilation_cache as _cc

                _cc.reset_cache()
            except (AttributeError, ImportError, TypeError, ValueError) as e:
                # older jax spellings only — anything else should surface.
                # degrading silently costs minutes of recompiles per process,
                # so say it once out loud.
                import warnings

                warnings.warn(
                    f"persistent XLA compile cache unavailable ({e!r}); "
                    "sweep processes will recompile from scratch",
                    RuntimeWarning, stacklevel=2)
                return None
            _COMPILE_CACHE_SET = True
    return path


def clear_cache() -> None:
    """Drop compiled executables (benchmarks call this to time cold runs)."""
    _JIT_CACHE.clear()
    _CACHE_STATS["builds"] = 0
    _CACHE_STATS["hits"] = 0
    for k in _OBS_STATS:
        _OBS_STATS[k] = 0


def _maybe_start_jax_trace() -> None:
    """Latch ``jax.profiler.start_trace`` on REPRO_JAX_TRACE_DIR: set the
    env var to a directory to capture a device-level profiler trace of the
    sweep dispatches (viewable in perfetto/tensorboard), stopped at process
    exit.  Off (and free) when unset."""
    global _JAX_TRACE_DIR
    path = os.environ.get("REPRO_JAX_TRACE_DIR")
    if not path or _JAX_TRACE_DIR is not None:
        return
    try:
        jax.profiler.start_trace(path)
    except Exception as e:  # pragma: no cover - backend-dependent
        import warnings

        warnings.warn(f"REPRO_JAX_TRACE_DIR set but start_trace failed "
                      f"({e!r})", RuntimeWarning, stacklevel=2)
        _JAX_TRACE_DIR = ""
        return
    _JAX_TRACE_DIR = path
    import atexit

    atexit.register(jax.profiler.stop_trace)


def _topo_key(topo: Topology, traced_cap: bool = False) -> tuple:
    """Value key so structurally identical Topology instances share one
    compilation.  Computed fresh every call — an id()-keyed memo would go
    stale when a collected topology's address is reused by a different one
    (the capacity hash is microseconds next to any simulation).

    ``traced_cap`` marks programs that take link capacity as a TRACED
    operand (co-sim fault schedules): the capacity VALUE then must not key
    the executable — every fault state shares one compilation — so the
    hash slot carries a sentinel instead."""
    cap = "traced" if traced_cap else \
        hashlib.sha1(np.asarray(topo.capacity).tobytes()).hexdigest()[:16]
    return (topo.kind, topo.n_leaf, topo.n_paths, topo.hosts_per_leaf,
            topo.n_links, topo.base_rtt_s, cap)


def _round_up(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def _f_bucket(F: int) -> int:
    """Power-of-two flow-count buckets (>= F_BUCKET): per-step cost is O(W),
    not O(F), so generous F padding is nearly free at runtime and maximizes
    compile reuse across traces of similar size."""
    b = F_BUCKET
    while b < F:
        b *= 2
    return b


_OP_NAMES = ("capacity", "loss", "reorder")


def _op_kw(ops_sig: tuple) -> tuple:
    """Traced-operand names selected by the (has_capacity, has_loss,
    has_reorder) signature — positional operands after (trace_arrays,
    finish0) map onto ``run_core`` keywords in this fixed order."""
    return tuple(n for n, has in zip(_OP_NAMES, ops_sig) if has)


def _gated_b1(topo: Topology, cfg: SimConfig, W: int, F_pad: int, A: int,
              n_steps: int, cap_seg_steps: int = 0, record=None,
              ops_sig: tuple = ()):
    """Single-sim callable over [1, ...]-leading inputs: no vmap wrapper,
    and the admission block gated behind a REAL lax.cond branch (vmap
    would lower it to both-branches + select) — once arrivals drain (3/4
    of the horizon on paper traces) the O(W) admission work is skipped
    outright.  Shared by the plain B=1 and the one-sim-per-device pmap
    dispatches.  Traced-operand dispatches pass extra UNBATCHED operands
    (capacity, loss, reorder — flagged by ``ops_sig``); the ``*ops``
    varargs map onto ``run_core`` keywords in that fixed order (same
    callable serves every arity — the executable cache key distinguishes
    them)."""
    core = functools.partial(compact.run_core, topo, cfg, W, F_pad, A,
                             n_steps, cap_seg_steps=cap_seg_steps,
                             gate_admission=True, record=record)
    names = _op_kw(ops_sig)

    def fn_one(trace_arrays, finish0, *ops):
        squeeze = lambda a: jnp.squeeze(a, 0)
        out = core(jax.tree.map(squeeze, trace_arrays),
                   jnp.squeeze(finish0, 0), **dict(zip(names, ops)))
        return jax.tree.map(lambda a: a[None], out)

    return fn_one


def _compiled(topo: Topology, cfg: SimConfig, W: int, F_pad: int, A: int,
              n_steps: int, batch: int, ops_sig: tuple = (),
              cap_seg_steps: int = 0, cap_rows: int = 1, record=None):
    """``ops_sig`` flags the traced operands after (trace_arrays, finish0)
    in the fixed order (capacity, loss, reorder) — e.g. (True, False, True)
    = capacity + reorder.  ``cap_seg_steps`` and ``cap_rows`` (K of a 2-D
    schedule) are static shape/stride facts that must key the executable
    alongside the shapes.  ``record`` (hashable ``obs.RecordSpec`` or None)
    keys the executable too: the ring buffer's shapes are a pure function
    of the spec, so recording costs exactly one extra program per (shape
    bucket, spec) and never a rebuild across epochs — the contract
    ``check_bench.py --obs`` gates."""
    key = (_topo_key(topo, bool(ops_sig)), cfg, W, F_pad, A, n_steps, batch,
           ops_sig, cap_seg_steps, cap_rows, record)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if batch == 1:
            fn = jax.jit(_gated_b1(topo, cfg, W, F_pad, A, n_steps,
                                   cap_seg_steps, record, ops_sig),
                         donate_argnums=(1,))
        else:
            core = functools.partial(compact.run_core, topo, cfg, W, F_pad,
                                     A, n_steps, cap_seg_steps=cap_seg_steps,
                                     record=record)
            names = _op_kw(ops_sig)

            def core_kw(trace_arrays, finish0, *ops):
                return core(trace_arrays, finish0, **dict(zip(names, ops)))

            in_axes = (0, 0) + (None,) * len(names)
            fn = jax.jit(jax.vmap(core_kw, in_axes=in_axes),
                         donate_argnums=(1,))
        _JIT_CACHE[key] = fn
        _CACHE_STATS["builds"] += 1
    else:
        _CACHE_STATS["hits"] += 1
    return fn


def sweep_devices() -> int:
    """Local devices the sweep runner will shard batches over.  Override
    with REPRO_SWEEP_DEVICES (e.g. 1 to force the plain vmap path)."""
    env = os.environ.get("REPRO_SWEEP_DEVICES")
    n = int(env) if env else jax.local_device_count()
    return max(1, min(n, jax.local_device_count()))


def _compiled_sharded(topo: Topology, cfg: SimConfig, W: int, F_pad: int,
                      A: int, n_steps: int, per_dev: int, n_dev: int,
                      ops_sig: tuple = (), cap_seg_steps: int = 0,
                      cap_rows: int = 1, record=None):
    """pmap-of-vmap executable: inputs carry a leading [n_dev, per_dev]
    batch, one shard per local device.  Each shard runs the identical
    vmapped compact scan, so per-sim results match the single-device path
    (same program, same shapes — only the dispatch is parallel).  Traced
    operands (capacity [+ loss] [+ reorder]) are broadcast to every device
    (in_axes None)."""
    key = (_topo_key(topo, bool(ops_sig)), cfg, W, F_pad, A, n_steps, per_dev,
           n_dev, ops_sig, cap_seg_steps, cap_rows, record, "pmap")
    fn = _JIT_CACHE.get(key)
    if fn is None:
        names = _op_kw(ops_sig)
        if per_dev == 1:
            # one sim per device: same gated, vmap-free core as the plain
            # batch==1 path
            inner = _gated_b1(topo, cfg, W, F_pad, A, n_steps, cap_seg_steps,
                              record, ops_sig)
        else:
            core = functools.partial(
                compact.run_core, topo, cfg, W, F_pad, A, n_steps,
                cap_seg_steps=cap_seg_steps, record=record)

            def core_kw(trace_arrays, finish0, *ops):
                return core(trace_arrays, finish0, **dict(zip(names, ops)))

            inner = jax.vmap(core_kw, in_axes=(0, 0) + (None,) * len(names))
        in_axes = (0, 0) + (None,) * len(names)
        fn = jax.pmap(inner, devices=jax.local_devices()[:n_dev],
                      donate_argnums=(1,), in_axes=in_axes)
        _JIT_CACHE[key] = fn
        _CACHE_STATS["builds"] += 1
    else:
        _CACHE_STATS["hits"] += 1
    return fn


# per-scheme lifetime slack for the concurrency bound: flowlet/hash schemes
# track near-ideal FCTs; SeqBalance holds more sub-flows.  DRILL's
# go-back-N collapse can blow far past any a-priori bound at high load —
# deliberately left at the default so the first (cheap) run doubles as the
# probe whose observed concurrency sizes the retry.
_SCHEME_SLACK = {
    "ecmp": (8.0, 100e-6),
    "letflow": (8.0, 100e-6),
    "conga": (8.0, 100e-6),
    "flowlet_timeout": (8.0, 100e-6),
    "seqbalance": (12.0, 150e-6),
}


def plan_window(topo: Topology, traces: list[Trace], *, scheme: str = "seqbalance",
                window_slots: int | None = None,
                sorted_arrays: list[tuple] | None = None) -> int:
    """Shared active-window size for a batch of traces (max of the per-trace
    concurrency bounds, bucketed)."""
    if window_slots is None:
        slack, extra = _SCHEME_SLACK.get(scheme, (12.0, 150e-6))
        line_rate = float(np.asarray(line_rate_of(topo)))
        if sorted_arrays is None:
            sorted_arrays = [compact.sort_trace(t)[0] for t in traces]
        window_slots = max(
            compact.max_concurrency_bound(
                a[0], a[1], a[5], line_rate, slack_slowdown=slack, slack_s=extra
            )
            for a in sorted_arrays
        )
    return _round_up(window_slots, W_BUCKET)


def _observed_concurrency(prepped, finish, horizon_s: float) -> int:
    """Max in-flight flow count actually seen in a (possibly spilled) run —
    spill delays admission, which only stretches flow lifetimes, so this
    upper-estimates the spill-free concurrency."""
    worst = 1
    for b, (arrays, _, F) in enumerate(prepped):
        valid = arrays[5][:F]
        a = arrays[1][:F][valid]  # sorted arrivals
        f = finish[b, :F][valid]
        f = np.where(np.isfinite(f), f, horizon_s)
        end = np.sort(f)
        started = np.arange(1, a.size + 1)
        ended = np.searchsorted(end, a, side="left")
        if a.size:
            worst = max(worst, int((started - ended).max()))
    return worst


def batch_mode() -> str:
    """How a single-device batch is dispatched: "persim" runs each trace
    through the (shared, cached) B=1 executable — on XLA:CPU that wins
    roughly 2x over one vmap: each sim keeps its own early exit instead of
    running to the batch's slowest, and the admission block is a real
    gated branch.  "vmap" restores the one-program-per-bucket batch (the
    right choice on accelerators with idle lanes).  Default: persim on
    cpu, vmap elsewhere; override with REPRO_SWEEP_BATCH."""
    mode = os.environ.get("REPRO_SWEEP_BATCH", "auto")
    if mode in ("persim", "vmap"):
        return mode
    return "persim" if jax.default_backend() == "cpu" else "vmap"


def _trace_span(name: str = "repro.sweep.dispatch"):
    """``jax.profiler`` annotation around a leaf dispatch: when a device
    trace is being captured (REPRO_JAX_TRACE_DIR -> ``start_trace``), the
    sweep executions show up as named spans in perfetto/tensorboard.
    Near-free when no trace is active."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - older jax spellings
        import contextlib

        return contextlib.nullcontext()


def _dispatch(topo, cfg, W, F_pad, A, n_steps, stacked, B, capacity=None,
              loss=None, cap_seg_steps=0, record=None, reorder=None):
    """Run a stacked [B, ...] batch, returning (finish, cnp, spill,
    ff_steps, outs) with a leading [B] axis.  >1 local device: pad B up to a multiple of
    the device count (duplicating the last row — padding results are
    sliced off) and run one pmap-of-vmap, one batch shard per device.
    Single device: per-sim B=1 executions (cpu) or one jitted vmap — see
    ``batch_mode``.  ``capacity`` (f32[n_links + 1] or a wall-clock
    schedule f32[K, n_links + 1] with static segment stride
    ``cap_seg_steps``, shared by the whole batch) rides along as a traced
    operand when given — fault-schedule sweeps then reuse one executable
    across capacity changes.  ``loss`` (f32[n_links + 1], requires
    ``capacity``) adds the per-link loss-rate operand for go-back-N
    goodput amplification (faults.LossyLink).  ``record`` (static
    ``obs.RecordSpec``) appends the in-sim ring buffer as a sixth output
    leaf with the same leading [B] axis."""
    assert loss is None or capacity is not None, \
        "loss operand requires an explicit capacity operand"
    assert reorder is None or capacity is not None, \
        "reorder operand requires an explicit capacity operand"
    ops = () if capacity is None else (jnp.asarray(capacity, jnp.float32),)
    if loss is not None:
        ops = ops + (jnp.asarray(loss, jnp.float32),)
    if reorder is not None:
        ops = ops + (jnp.asarray(reorder, jnp.float32),)
    ops_sig = (capacity is not None, loss is not None, reorder is not None)
    cap_rows = ops[0].shape[0] if ops and ops[0].ndim == 2 else 1
    D = sweep_devices()
    if D > 1 and B > 1:
        D = min(D, B)
        Bp = -(-B // D) * D
        if Bp > B:
            stacked = tuple(
                np.concatenate([a, np.repeat(a[-1:], Bp - B, axis=0)])
                for a in stacked
            )
        per = Bp // D
        shaped = tuple(
            jnp.asarray(a.reshape((D, per) + a.shape[1:])) for a in stacked
        )
        fn = _compiled_sharded(topo, cfg, W, F_pad, A, n_steps, per, D,
                               ops_sig, cap_seg_steps, cap_rows, record)
        finish0 = jnp.full((D, per, F_pad), jnp.inf, jnp.float32)
        with _trace_span():
            out = fn(shaped, finish0, *ops)
        return jax.tree.map(
            lambda a: jnp.reshape(a, (Bp,) + a.shape[2:])[:B], out
        )
    if B > 1 and batch_mode() == "persim":
        # every sim in the bucket shares (W, F_pad, A) -> ONE compiled B=1
        # program serves the whole loop
        parts = [
            _dispatch(topo, cfg, W, F_pad, A, n_steps,
                      tuple(a[i:i + 1] for a in stacked), 1, capacity,
                      loss, cap_seg_steps, record, reorder)
            for i in range(B)
        ]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    fn = _compiled(topo, cfg, W, F_pad, A, n_steps, B, ops_sig, cap_seg_steps,
                   cap_rows, record)
    finish0 = jnp.full((B, F_pad), jnp.inf, jnp.float32)
    with _trace_span():
        return fn(tuple(jnp.asarray(a) for a in stacked), finish0, *ops)


def _run_group(topo, cfg, prepped, n_steps, window_slots, capacity=None,
               loss=None, cap_seg_steps=0, record=None, reorder=None):
    """One vmapped run over traces sharing an F_pad bucket, with the
    spill-retry loop: the concurrency bound is a heuristic, so any sim that
    reports spill_steps > 0 (an arrived flow found no free slot — its
    admission was delayed, which would diverge from the dense oracle) is
    rerun with a window re-planned from the concurrency it actually
    exhibited.  Spill-free sims keep their first-run results — only the
    offenders pay the retry."""
    F_pad = _f_bucket(max(F for (_, _, F) in prepped))
    if window_slots is not None:
        # explicit window: honor it exactly (tests probe the retry path)
        W = max(8, min(int(window_slots), F_pad))
    else:
        W = min(plan_window(topo, [], scheme=cfg.scheme,
                            sorted_arrays=[a for (a, _, _) in prepped]), F_pad)
    A = _round_up(max(compact.max_admits_per_step(a[1], a[5], cfg.dt)
                      for (a, _, _) in prepped), 32)
    A = min(A, F_pad)
    padded = [compact.pad_trace_arrays(a, F_pad) for (a, _, _) in prepped]
    results: list = [None] * len(prepped)
    outs_list: list = [None] * len(prepped)
    pending = list(range(len(prepped)))
    while pending:
        stacked = tuple(
            np.stack([padded[i][k] for i in pending])
            for k in range(len(padded[0]))
        )
        t0 = time.time()
        out = _dispatch(
            topo, cfg, W, F_pad, A, n_steps, stacked, len(pending), capacity,
            loss, cap_seg_steps, record, reorder)
        finish, cnp, spill, ff, outs = out[:5]
        ring = out[5] if len(out) > 5 else None
        spill = np.asarray(spill)
        finish = np.asarray(finish)
        cnp = np.asarray(cnp)
        ff = np.asarray(ff)
        if os.environ.get("REPRO_SWEEP_DEBUG"):
            print(f"# sweep {cfg.scheme} B={len(pending)} F_pad={F_pad} W={W} "
                  f"A={A} spill={spill.tolist()} wall={time.time()-t0:.1f}s",
                  flush=True)
        still, still_rows = [], []
        for b, i in enumerate(pending):
            if spill[b] == 0 or W >= F_pad:
                _, inv, F = prepped[i]
                results[i] = compact.CompactResult(
                    finish=finish[b, :F][inv], cnp_pkts=cnp[b],
                    spill_steps=int(spill[b]), window_slots=W,
                    ff_steps=int(ff[b]),
                    ring=None if ring is None
                    else jax.tree.map(lambda a, b=b: a[b], ring),
                )
                outs_list[i] = jax.tree.map(lambda a, b=b: a[b], outs)
            else:
                still.append(i)
                still_rows.append(b)
        pending = still
        if pending:
            _OBS_STATS["spill_retries"] += 1
            seen = _observed_concurrency(
                [prepped[i] for i in pending], finish[still_rows], n_steps * cfg.dt
            )
            W = min(max(W * 2, _round_up(int(seen * 1.2) + 64, W_BUCKET)), F_pad)
            A = min(A * 2, F_pad)
    return results, outs_list


def run_batch(
    topo: Topology,
    cfg: SimConfig,
    traces: list[Trace],
    *,
    window_slots: int | None = None,
    capacity: np.ndarray | None = None,
    loss: np.ndarray | None = None,
    cap_seg_steps: int = 0,
    record=None,
    reorder: float | None = None,
) -> tuple[list[compact.CompactResult], list[StepOutputs]]:
    """Run every trace under one (scheme, topology) static pair as vmapped,
    donated, cached-compile computations — one per F_pad shape bucket, so a
    small trace is never padded to a 30x larger sibling's shape.

    ``capacity`` (f32[n_links + 1], sentinel slot included) overrides
    ``topo.capacity`` as a TRACED operand shared by the whole batch: co-sim
    fault schedules change link capacities per planning epoch, and threading
    them as data means every epoch reuses the one compiled program (the
    executable cache keys on a "traced" sentinel instead of the capacity
    hash — see ``cache_stats``).  A 2-D schedule f32[K, n_links + 1] plus a
    static ``cap_seg_steps`` stride extends that to wall-clock fault onsets
    (faults.FaultCampaign).  ``loss`` (f32[n_links + 1]) adds the per-link
    loss-rate operand (lossy-link go-back-N amplification); capacity is
    promoted to ``topo.capacity`` automatically if only loss is given.

    ``record`` (an ``obs.RecordSpec``) turns on the in-sim flight recorder:
    each result's ``ring`` field carries the per-chunk summary ring
    (drain with ``obs.drain``).  ``record=None`` is bit-identical to the
    recorder not existing.

    ``reorder`` (scalar, packets) turns on the flowcell reordering-cost
    model: flows whose trace ``spray`` column exceeds 1 pay a go-back-N
    amplification from inter-path skew beyond the budget
    (``dataplane.reorder_gbn_factor``).  Like loss it is a TRACED operand —
    one compiled program covers every budget value and every split factor —
    and ``reorder=None`` traces the identical pre-flowcell program."""
    assert traces, "empty sweep"
    enable_compile_cache()
    _maybe_start_jax_trace()
    if (loss is not None or reorder is not None) and capacity is None:
        capacity = np.asarray(topo.capacity)
    prepped = [compact.sort_trace(t) for t in traces]
    n_steps = int(round(cfg.duration_s / cfg.dt))
    groups: dict[int, list[int]] = {}
    for i, (_, _, F) in enumerate(prepped):
        groups.setdefault(_f_bucket(F), []).append(i)
    results: list = [None] * len(traces)
    outs_list: list = [None] * len(traces)
    for idxs in groups.values():
        res, outs = _run_group(topo, cfg, [prepped[i] for i in idxs], n_steps,
                               window_slots, capacity, loss, cap_seg_steps,
                               record, reorder)
        for i, r, o in zip(idxs, res, outs):
            results[i] = r
            outs_list[i] = o
    return results, outs_list


def run_one(topo: Topology, cfg: SimConfig, trace: Trace, *,
            window_slots: int | None = None,
            capacity: np.ndarray | None = None,
            loss: np.ndarray | None = None,
            cap_seg_steps: int = 0,
            record=None,
            reorder: float | None = None):
    results, outs = run_batch(topo, cfg, [trace], window_slots=window_slots,
                              capacity=capacity, loss=loss,
                              cap_seg_steps=cap_seg_steps, record=record,
                              reorder=reorder)
    return results[0], outs[0]


def default_workers(n_jobs: int) -> int:
    """run_jobs worker count: REPRO_SWEEP_WORKERS if set (>=1), else
    ``os.cpu_count()`` capped at the job count."""
    env = os.environ.get("REPRO_SWEEP_WORKERS")
    if env:
        return max(1, min(int(env), max(n_jobs, 1)))
    return max(1, min(n_jobs, os.cpu_count() or 1))


def _run_job(job):
    """One ``run_jobs`` entry.  Three spellings:

      * ``(topo, cfg, traces)``           — the classic per-scheme sweep;
      * ``(topo, cfg, traces, kwargs)``   — same, with ``run_batch`` keyword
        overrides (``capacity=...`` for fault-schedule grids,
        ``window_slots=...``);
      * any zero-argument callable        — an arbitrary multi-step job,
        e.g. one ``dist.cosim.run_cosim`` epoch loop per (scheme, ring,
        fault, seed) grid point.  The callable runs on the worker thread
        and its sweeps go through the same cached-executable dispatch.
    """
    if callable(job):
        return job()
    topo, cfg, traces, *rest = job
    kw = dict(rest[0]) if rest else {}
    return run_batch(topo, cfg, traces, **kw)


@dataclasses.dataclass(frozen=True)
class JobFailure:
    """Poisoned record a salvaged grid cell returns instead of its result:
    the grid completes, the failure stays visible and attributable.  Check
    ``isinstance(r, sweep.JobFailure)`` (or the ``failed`` marker) before
    consuming grid results from a salvaging run."""

    index: int  # position in the run_jobs list (results stay in job order)
    attempts: int
    error: str  # "ExcType: message" of the last attempt
    elapsed_s: float
    timed_out: bool = False

    @property
    def failed(self) -> bool:
        return True


def retry_sleep_s(index: int, attempt: int, backoff_s: float,
                  jitter_frac: float) -> float:
    """Jittered exponential backoff for retry ``attempt`` of job ``index``:
    base ``backoff_s * 2**(attempt-1)`` (capped at 30 s) stretched by a
    uniform factor in [1, 1 + jitter_frac].  The jitter is DETERMINISTIC —
    seeded on (index, attempt) — so tests replay it exactly, yet
    decorrelated across jobs: a pool of cells that all failed together
    (one flaky dependency hiccup) re-arrives spread out instead of as a
    synchronized retry storm re-hammering whatever just recovered.
    ``backoff_s == 0`` sleeps 0 regardless of jitter (the test fast path)."""
    base = min(backoff_s * (2 ** (attempt - 1)), 30.0)
    if base <= 0.0 or jitter_frac <= 0.0:
        return base
    u = float(np.random.default_rng((index, attempt)).uniform())
    return base * (1.0 + jitter_frac * u)


def _run_job_resilient(job, index: int, *, retries: int, backoff_s: float,
                       salvage: bool, jitter_frac: float = 0.5):
    t0 = time.time()
    for attempt in range(1, retries + 2):
        try:
            return _run_job(job)
        except Exception as e:  # noqa: BLE001 — grid cells fail arbitrarily
            if attempt <= retries:
                _OBS_STATS["job_retries"] += 1
                time.sleep(retry_sleep_s(index, attempt, backoff_s,
                                         jitter_frac))
                continue
            if not salvage:
                raise
            _OBS_STATS["job_failures"] += 1
            return JobFailure(index=index, attempts=attempt,
                              error=f"{type(e).__name__}: {e}",
                              elapsed_s=time.time() - t0)
    raise AssertionError("unreachable")


def run_jobs(
    jobs: list,
    *,
    workers: int | None = None,
    timeout_s: float | None = None,
    retries: int = 0,
    backoff_s: float = 0.5,
    jitter_frac: float = 0.5,
    salvage: bool = False,
) -> list:
    """Run independent sweep jobs (e.g. one per scheme, or one co-sim epoch
    loop per grid point — see ``_run_job`` for the accepted spellings)
    concurrently.

    XLA's CPU executables release the GIL, so a small thread pool overlaps
    independent compiles and scans across cores — the five-scheme Fig. 12
    sweep and the (scheme x ring x fault x seed) co-sim grids are
    embarrassingly parallel at this level.  Results are returned in job
    order, identical to serial execution.

    Crash-proofing (all off by default — the bare call is unchanged):

      * ``retries``   — re-run a raising job up to this many extra times,
        sleeping ``backoff_s * 2**attempt`` (capped at 30 s, stretched by
        the seeded per-(job, attempt) jitter of ``retry_sleep_s`` so
        simultaneous failures don't retry as a synchronized storm;
        ``jitter_frac=0`` disables) between tries; transient failures
        (OOM races, flaky I/O) get a second chance.
      * ``salvage``   — a job that still fails returns a ``JobFailure``
        poisoned record IN PLACE, instead of propagating and killing every
        other cell of the grid; the caller decides what a dead cell costs.
      * ``timeout_s`` — advisory per-job cap, enforced at collection time
        (threads cannot be killed: a stuck job's slot is abandoned — its
        cell salvages as ``timed_out`` — but the worker thread itself only
        dies with the process).  Ignored on the serial (workers == 1)
        path, where there is no second thread to collect from.

    Worker count resolution: explicit ``workers`` argument, else the
    REPRO_SWEEP_WORKERS env var, else a capped ``os.cpu_count()``."""
    import concurrent.futures as cf

    enable_compile_cache()  # once, before worker threads race to compile
    if workers is None:
        workers = default_workers(len(jobs))
    if workers == 1 or len(jobs) == 1:
        return [
            _run_job_resilient(j, i, retries=retries, backoff_s=backoff_s,
                               salvage=salvage, jitter_frac=jitter_frac)
            for i, j in enumerate(jobs)
        ]
    pool = cf.ThreadPoolExecutor(max_workers=workers)
    timed_out = False
    try:
        futs = [
            pool.submit(_run_job_resilient, j, i, retries=retries,
                        backoff_s=backoff_s, salvage=salvage,
                        jitter_frac=jitter_frac)
            for i, j in enumerate(jobs)
        ]
        out = []
        for i, f in enumerate(futs):
            try:
                out.append(f.result(timeout=timeout_s))
            except cf.TimeoutError:
                timed_out = True
                _OBS_STATS["job_timeouts"] += 1
                if not salvage:
                    raise
                out.append(JobFailure(index=i, attempts=1,
                                      error="TimeoutError: job still running",
                                      elapsed_s=float(timeout_s or 0.0),
                                      timed_out=True))
        return out
    finally:
        # a hung job's thread cannot be killed — but shutdown(wait=True)
        # would BLOCK the whole pool behind it, turning one stuck cell back
        # into a wedged sweep.  Abandon the slot; the thread dies with the
        # process (exactly the advisory contract documented above).
        pool.shutdown(wait=not timed_out, cancel_futures=timed_out)
