"""Datacenter topologies for the netsim engine (paper §IV).

Two builders, matching the paper's evaluation setups:

  * ``leaf_spine``  — 2-tier Clos (testbed: 2 leaves x 4 spines x 3 hosts
    @40G; large sim: 8 leaves x 12 spines x 16 hosts @100G).  A path between
    two leaves is identified by the spine it crosses -> n_paths = n_spine.
  * ``three_tier``  — the paper's "FatTree" (16 core / 20 agg / 20 ToR /
    16 hosts per ToR; ToR-agg 400G, others 100G).  We model it as a folded
    Clos with full bipartite ToR<->Agg and Agg<->Core and symmetric
    up/down routing, so a path is (agg, core): n_paths = n_agg * n_core =
    320 <= 1023, which — pleasingly — fits the paper's 10-bit PathTag.

Links live in one flat capacity vector; every (sub-)flow touches at most
``MAX_HOPS`` links: [host_tx, up1, (up2), (dn1), dn2, host_rx] (-1 = hop
absent; 2-tier emits the compact 4-hop form).  The engine scatter-adds
offered rates over these ids (the same computation the linkload Pallas
kernel implements for the TPU target).

Asymmetry (paper Fig. 8b/11): ``capacity_overrides`` rescales individual
links — e.g. kill spine 3 and double spine 2's leaf links to 80G.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

MAX_HOPS = 6


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash so the
class Topology:  # instance can be a jit static argument (fields hold arrays)
    """Static topology description (closed over by the jitted engine)."""

    kind: str
    n_leaf: int
    n_paths: int
    hosts_per_leaf: int
    n_links: int
    capacity: jax.Array  # f32[n_links + 1] bps; last slot = dummy sink for -1
    # f(src_host, dst_host, path) -> int32[..., MAX_HOPS] link ids (-1 pad)
    subflow_links: Callable
    # NIC-tiered view of the same hop vector (dataplane.cascade_nic): every
    # sub-flow of a flow shares its first (host_tx) and last (host_rx) hop,
    # and the fabric hops depend only on (src_leaf, dst_leaf, path) — so the
    # NIC hops can be pre-reduced over N and the fabric hops rebuilt without
    # touching host ids.
    # f(src_host, dst_host) -> (tx i32[...], rx i32[...])
    nic_links: Callable
    # f(src_leaf, dst_leaf, path) -> i32[..., n_fabric_hops] (-1 = absent)
    fabric_links: Callable
    n_fabric_hops: int
    # fabric-only view used for congestion metrics / imbalance:
    uplink_ids: np.ndarray  # int32[n_leaf, n_uplinks] — ToR uplink link ids
    base_rtt_s: float
    # (leaf, path) -> util: engine computes from link loads via these ids
    path_link_table: np.ndarray  # int32[n_leaf, n_leaf, n_paths, MAX_HOPS-2] fabric hops

    @property
    def n_hosts(self) -> int:
        return self.n_leaf * self.hosts_per_leaf

    def leaf_of(self, host):
        return host // self.hosts_per_leaf


def _apply_overrides(cap: np.ndarray, overrides):
    for link_id, new_cap in (overrides or {}).items():
        cap[link_id] = new_cap
    return cap


def leaf_spine(
    n_leaf: int,
    n_spine: int,
    hosts_per_leaf: int,
    link_bw: float,
    host_bw: float | None = None,
    base_rtt_s: float = 4e-6,
    capacity_overrides: dict[int, float] | None = None,
) -> Topology:
    """2-tier Clos.  Link layout:
    up[l,s]   = l*S + s
    down[s,l] = L*S + s*L + l
    host_tx[h]= L*S + S*L + h
    host_rx[h]= L*S + S*L + H + h
    """
    L, S, H = n_leaf, n_spine, n_leaf * hosts_per_leaf
    host_bw = link_bw if host_bw is None else host_bw
    n_links = L * S + S * L + 2 * H
    cap = np.zeros(n_links + 1, np.float32)
    cap[: L * S] = link_bw
    cap[L * S : 2 * L * S] = link_bw
    cap[2 * L * S : 2 * L * S + 2 * H] = host_bw
    cap[-1] = np.float32(1e30)  # dummy sink — -1 hops land here
    cap = _apply_overrides(cap, capacity_overrides)

    up0, dn0, tx0, rx0 = 0, L * S, 2 * L * S, 2 * L * S + H

    def nic_links(src_host, dst_host):
        tx = jnp.asarray(tx0 + src_host, jnp.int32)
        rx = jnp.asarray(rx0 + dst_host, jnp.int32)
        return jnp.broadcast_arrays(tx, rx)

    def fabric_links(src_leaf, dst_leaf, path):
        shp = jnp.broadcast_shapes(jnp.shape(src_leaf), jnp.shape(dst_leaf), jnp.shape(path))
        src_leaf, dst_leaf, path = (jnp.broadcast_to(a, shp) for a in (src_leaf, dst_leaf, path))
        inter = src_leaf != dst_leaf
        up = jnp.where(inter, up0 + src_leaf * S + path, -1)
        dn = jnp.where(inter, dn0 + path * L + dst_leaf, -1)
        return jnp.stack([up, dn], axis=-1).astype(jnp.int32)

    def subflow_links(src_host, dst_host, path):
        # 4 real hops (no -1 padding columns): the dataplane cascade cost is
        # linear in the hop count, so 2-tier flows carry a [.., 4] hop
        # vector while three_tier keeps the full MAX_HOPS = 6.
        shp = jnp.broadcast_shapes(jnp.shape(src_host), jnp.shape(dst_host), jnp.shape(path))
        src_host, dst_host, path = (jnp.broadcast_to(a, shp) for a in (src_host, dst_host, path))
        tx, rx = nic_links(src_host, dst_host)
        fab = fabric_links(src_host // hosts_per_leaf, dst_host // hosts_per_leaf, path)
        return jnp.concatenate(
            [tx[..., None], fab, rx[..., None]], axis=-1
        ).astype(jnp.int32)

    uplink_ids = (np.arange(L)[:, None] * S + np.arange(S)[None, :]).astype(np.int32)

    plt = np.full((L, L, S, MAX_HOPS - 2), -1, np.int32)
    for sl in range(L):
        for dl in range(L):
            if sl == dl:
                continue
            for p in range(S):
                plt[sl, dl, p, 0] = up0 + sl * S + p
                plt[sl, dl, p, 3] = dn0 + p * L + dl
    return Topology(
        kind="leaf_spine",
        n_leaf=L,
        n_paths=S,
        hosts_per_leaf=hosts_per_leaf,
        n_links=n_links,
        capacity=jnp.asarray(cap),
        subflow_links=subflow_links,
        nic_links=nic_links,
        fabric_links=fabric_links,
        n_fabric_hops=2,
        uplink_ids=uplink_ids,
        base_rtt_s=base_rtt_s,
        path_link_table=plt,
    )


def three_tier(
    n_tor: int = 20,
    n_agg: int = 20,
    n_core: int = 16,
    hosts_per_tor: int = 16,
    bw_tor_agg: float = 400e9,
    bw_agg_core: float = 100e9,
    host_bw: float = 100e9,
    base_rtt_s: float = 8e-6,
    capacity_overrides: dict[int, float] | None = None,
) -> Topology:
    """3-tier folded Clos (paper Fig. 14 setup).  Path id = agg*n_core+core.
    Link layout:
      ta_up[t,a] = t*A + a
      ac_up[a,c] = T*A + a*C + c
      ca_dn[c,a] = T*A + A*C + c*A + a
      at_dn[a,t] = T*A + 2*A*C + a*T + t
      host_tx[h], host_rx[h] appended.
    """
    T, A, C = n_tor, n_agg, n_core
    H = T * hosts_per_tor
    n_links = T * A + 2 * A * C + A * T + 2 * H
    cap = np.zeros(n_links + 1, np.float32)
    ta0, ac0 = 0, T * A
    ca0 = T * A + A * C
    at0 = T * A + 2 * A * C
    tx0 = T * A + 2 * A * C + A * T
    rx0 = tx0 + H
    cap[ta0 : ta0 + T * A] = bw_tor_agg
    cap[ac0 : ac0 + A * C] = bw_agg_core
    cap[ca0 : ca0 + C * A] = bw_agg_core
    cap[at0 : at0 + A * T] = bw_tor_agg
    cap[tx0 : tx0 + 2 * H] = host_bw
    cap[-1] = np.float32(1e30)
    cap = _apply_overrides(cap, capacity_overrides)

    def nic_links(src_host, dst_host):
        tx = jnp.asarray(tx0 + src_host, jnp.int32)
        rx = jnp.asarray(rx0 + dst_host, jnp.int32)
        return jnp.broadcast_arrays(tx, rx)

    def fabric_links(src_tor, dst_tor, path):
        shp = jnp.broadcast_shapes(jnp.shape(src_tor), jnp.shape(dst_tor), jnp.shape(path))
        src_tor, dst_tor, path = (jnp.broadcast_to(a, shp) for a in (src_tor, dst_tor, path))
        inter = src_tor != dst_tor
        agg = path // C
        core = path % C
        up1 = jnp.where(inter, ta0 + src_tor * A + agg, -1)
        up2 = jnp.where(inter, ac0 + agg * C + core, -1)
        dn1 = jnp.where(inter, ca0 + core * A + agg, -1)
        dn2 = jnp.where(inter, at0 + agg * T + dst_tor, -1)
        return jnp.stack([up1, up2, dn1, dn2], axis=-1).astype(jnp.int32)

    def subflow_links(src_host, dst_host, path):
        shp = jnp.broadcast_shapes(jnp.shape(src_host), jnp.shape(dst_host), jnp.shape(path))
        src_host, dst_host, path = (jnp.broadcast_to(a, shp) for a in (src_host, dst_host, path))
        tx, rx = nic_links(src_host, dst_host)
        fab = fabric_links(src_host // hosts_per_tor, dst_host // hosts_per_tor, path)
        return jnp.concatenate(
            [tx[..., None], fab, rx[..., None]], axis=-1
        ).astype(jnp.int32)

    uplink_ids = (np.arange(T)[:, None] * A + np.arange(A)[None, :]).astype(np.int32)

    # path_link_table would be [20,20,320,4] = 512k int32 — built lazily by
    # schemes that need it (CONGA is 2-tier-only per the paper, so none do).
    plt = np.zeros((0,), np.int32)
    return Topology(
        kind="three_tier",
        n_leaf=T,
        n_paths=A * C,
        hosts_per_leaf=hosts_per_tor,
        n_links=n_links,
        capacity=jnp.asarray(cap),
        subflow_links=subflow_links,
        nic_links=nic_links,
        fabric_links=fabric_links,
        n_fabric_hops=4,
        uplink_ids=uplink_ids,
        base_rtt_s=base_rtt_s,
        path_link_table=plt,
    )


def spine_links(topo: Topology, spine: int) -> tuple[int, ...]:
    """Flat link ids that die with one fabric switch — the unit of the
    co-sim fault schedules (``dist.cosim``).

    * ``leaf_spine``: ``spine`` is a spine switch — its leaf uplinks
      up[l, spine] and downlinks down[spine, l] for every leaf l.
    * ``three_tier``: ``spine`` is an AGGREGATION switch a — the ToR
      uplinks ta[t, a], agg-core links ac[a, c] / ca[c, a], and ToR
      downlinks at[a, t].  Killing it takes out ToR uplink a on every ToR,
      i.e. exactly the ``n_core`` paths (a, *) that
      ``dist.netfeed._paths_for_uplink`` quarantines.
    """
    if topo.kind == "leaf_spine":
        L, S = topo.n_leaf, topo.n_paths
        assert 0 <= spine < S, (spine, S)
        return tuple(l * S + spine for l in range(L)) + tuple(
            L * S + spine * L + l for l in range(L))
    assert topo.kind == "three_tier", topo.kind
    T = topo.n_leaf
    A = topo.uplink_ids.shape[1]
    C = topo.n_paths // A
    assert 0 <= spine < A, (spine, A)
    ta0, ac0 = 0, T * A
    ca0 = T * A + A * C
    at0 = T * A + 2 * A * C
    return (
        tuple(ta0 + t * A + spine for t in range(T))
        + tuple(ac0 + spine * C + c for c in range(C))
        + tuple(ca0 + c * A + spine for c in range(C))
        + tuple(at0 + spine * T + t for t in range(T))
    )


def paths_for_link(topo: Topology, link: int) -> tuple[int, ...]:
    """Inverse of the fabric hop layout: which path ids traverse flat link
    ``link``.  Host tx/rx links (and the dummy sink) belong to no path ->
    empty tuple.  Used by the fault layer to turn a per-LINK event (a
    flapping port, a lossy optic) into the per-PATH quarantine set the
    planner speaks (``dist.netfeed.report_congestion``, in-epoch
    replanning in ``dist.cosim``).

    * ``leaf_spine``: up[l, s] and down[s, l] both map to path s.
    * ``three_tier`` (path = agg * C + core): ToR up/downlinks of agg a
      cover all C paths (a, *); agg<->core links pin a single (agg, core).
    """
    if topo.kind == "leaf_spine":
        L, S = topo.n_leaf, topo.n_paths
        if link < L * S:  # up[l, s] = l*S + s
            return (link % S,)
        if link < 2 * L * S:  # down[s, l] = L*S + s*L + l
            return ((link - L * S) // L,)
        return ()
    assert topo.kind == "three_tier", topo.kind
    T = topo.n_leaf
    A = topo.uplink_ids.shape[1]
    C = topo.n_paths // A
    ta0, ac0 = 0, T * A
    ca0 = T * A + A * C
    at0 = T * A + 2 * A * C
    tx0 = at0 + A * T
    if link < ac0:  # ta[t, a] = t*A + a
        a = link % A
        return tuple(a * C + c for c in range(C))
    if link < ca0:  # ac[a, c] = ac0 + a*C + c
        i = link - ac0
        return ((i // C) * C + (i % C),)
    if link < at0:  # ca[c, a]
        i = link - ca0
        c, a = i // A, i % A
        return (a * C + c,)
    if link < tx0:  # at[a, t] = at0 + a*T + t
        a = (link - at0) // T
        return tuple(a * C + c for c in range(C))
    return ()


def testbed_symmetric() -> Topology:
    """Paper Fig. 8(a): 2 leaves x 4 spines, 3 hosts/leaf, all 40G."""
    return leaf_spine(2, 4, 3, 40e9, base_rtt_s=4e-6)


def testbed_asymmetric() -> Topology:
    """Paper Fig. 8(b): one spine deactivated and its links redirected to a
    neighbour -> 3 usable paths, one of them 80G while the rest stay 40G.
    ECMP still hashes uniformly over the 3 paths (it cannot see the extra
    capacity); SeqBalance's congestion feedback steers load toward the fat
    path — the paper measures +37.6 % total throughput from this."""
    L, S = 2, 3
    overrides = {}
    for leaf in range(L):
        overrides[leaf * S + 2] = 80e9  # up[l,2] doubled
        overrides[L * S + 2 * L + leaf] = 80e9  # down[2,l] doubled
    return leaf_spine(2, 3, 3, 40e9, base_rtt_s=4e-6, capacity_overrides=overrides)


def sim_2tier() -> Topology:
    """Paper §IV.B: 8 leaves x 12 spines x 16 hosts, 100G everywhere."""
    return leaf_spine(8, 12, 16, 100e9, base_rtt_s=4e-6)


def hetero_leaf_spine(
    n_leaf: int = 4,
    n_spine: int = 4,
    hosts_per_leaf: int = 4,
    slow_bw: float = 100e9,
    fast_bw: float = 400e9,
    n_fast_spines: int = 1,
    host_bw: float | None = None,
    base_rtt_s: float = 4e-6,
) -> Topology:
    """Mixed-speed 2-tier Clos: the last ``n_fast_spines`` spine planes run
    at ``fast_bw`` (both the leaf uplinks up[l, s] and the downlinks
    down[s, l]), the rest at ``slow_bw`` — the 100G/400G mixed-uplink
    fabrics that mid-upgrade clusters actually run.  Hosts stay at
    ``slow_bw`` unless overridden, so the fabric asymmetry (not the edge)
    is the bottleneck the balancer must exploit.

    Hash-based schemes (ECMP, per-flowcell spraying) split uniformly over
    the planes and leave the fast spines underfed; capacity-weighted
    flowlet rerouting (``flowlet_timeout``, WCMP weights from these link
    speeds) and SeqBalance's congestion feedback both see the extra
    headroom.  The inter-path delivery-time skew that the flowcell
    reordering-cost model (``dataplane.reorder_gbn_factor``) charges for is
    also largest here: a cell on a 100G plane trails its 400G sibling 4x.
    """
    assert 0 <= n_fast_spines <= n_spine, (n_fast_spines, n_spine)
    L, S = n_leaf, n_spine
    overrides: dict[int, float] = {}
    for s in range(S - n_fast_spines, S):
        for leaf in range(L):
            overrides[leaf * S + s] = fast_bw  # up[l, s]
            overrides[L * S + s * L + leaf] = fast_bw  # down[s, l]
    return leaf_spine(L, S, hosts_per_leaf, slow_bw, host_bw=host_bw,
                      base_rtt_s=base_rtt_s, capacity_overrides=overrides)
