"""Traffic workloads (paper Fig. 9): AliCloud-Storage and WebSearch.

Flow sizes are drawn from empirical CDFs; arrivals are Poisson at a rate
chosen to hit a target average load on the host-uplink capacity:

    lambda = load * n_hosts * host_bw / (8 * mean_size_bytes)

The CDF tables are the published ones: WebSearch from the DCTCP paper
(Alizadeh et al., SIGCOMM'10) and AliCloud Storage digitized from HPCC
(Li et al., SIGCOMM'19) — both are the sources the paper itself cites for
its Fig. 9.  Sampling happens in numpy up front; the engine consumes plain
arrays (sizes, arrival times, src/dst hosts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (size_bytes, cumulative_probability)
WEBSEARCH_CDF = np.array(
    [
        (1_000, 0.00),
        (10_000, 0.15),
        (20_000, 0.20),
        (30_000, 0.30),
        (50_000, 0.40),
        (80_000, 0.53),
        (200_000, 0.60),
        (1_000_000, 0.70),
        (2_000_000, 0.80),
        (5_000_000, 0.90),
        (10_000_000, 0.97),
        (30_000_000, 1.00),
    ],
    dtype=np.float64,
)

ALISTORAGE_CDF = np.array(
    [
        (1_000, 0.00),
        (2_000, 0.10),
        (4_000, 0.30),
        (8_000, 0.50),
        (16_000, 0.65),
        (32_000, 0.80),
        (64_000, 0.90),
        (100_000, 0.95),
        (256_000, 0.98),
        (1_000_000, 0.99),
        (2_000_000, 1.00),
    ],
    dtype=np.float64,
)

WORKLOADS = {"websearch": WEBSEARCH_CDF, "alistorage": ALISTORAGE_CDF}


def cdf_mean(cdf: np.ndarray) -> float:
    """Mean flow size implied by the piecewise-linear CDF."""
    sizes, probs = cdf[:, 0], cdf[:, 1]
    mids = (sizes[1:] + sizes[:-1]) / 2
    masses = np.diff(probs)
    return float((mids * masses).sum())


def sample_sizes(cdf: np.ndarray, n: int, rng: np.random.Generator) -> np.ndarray:
    """Inverse-transform sampling with linear interpolation between knots."""
    u = rng.uniform(0.0, 1.0, n)
    return np.interp(u, cdf[:, 1], cdf[:, 0]).astype(np.float32)


@dataclasses.dataclass
class TraceConfig:
    workload: str  # "websearch" | "alistorage" | "fixed:<bytes>"
    load: float  # fraction of ``load_base_bw`` (defaults to host aggregate)
    duration_s: float
    n_hosts: int
    host_bw: float
    seed: int = 0
    inter_rack_only: bool = True
    hosts_per_leaf: int = 16
    max_flows: int | None = None  # cap (padded arrays); None = exact
    # aggregate bps that ``load`` multiplies.  For fabric-bound topologies
    # (e.g. 128 hosts over 96 uplinks) pass the bisection capacity so that
    # "80% load" means 80% MEAN FABRIC UTILIZATION, as in the paper's sims.
    load_base_bw: float | None = None


@dataclasses.dataclass
class Trace:
    sizes: np.ndarray  # f32[F] bytes
    arrivals: np.ndarray  # f32[F] seconds
    src: np.ndarray  # i32[F]
    dst: np.ndarray  # i32[F]
    flow_id: np.ndarray  # u32[F]
    valid: np.ndarray  # bool[F] (padding mask)
    # paths the flow's parent chunk straddles (flowcell splitting): 1 means
    # the flow is alone on its path — no reordering possible, and the
    # dataplane's reorder_gbn_factor is exactly 1 there.  Defaults to all
    # ones so every pre-flowcell constructor keeps its meaning.
    spray: np.ndarray | None = None  # i32[F]

    def __post_init__(self):
        if self.spray is None:
            self.spray = np.ones(np.shape(self.src)[0], np.int32)


def poisson_trace(cfg: TraceConfig) -> Trace:
    rng = np.random.default_rng(cfg.seed)
    if cfg.workload.startswith("fixed:"):
        mean = float(cfg.workload.split(":", 1)[1])
        sampler = lambda n: np.full(n, mean, np.float32)
    else:
        cdf = WORKLOADS[cfg.workload]
        mean = cdf_mean(cdf)
        sampler = lambda n: sample_sizes(cdf, n, rng)

    base = cfg.load_base_bw if cfg.load_base_bw is not None else cfg.n_hosts * cfg.host_bw
    lam = cfg.load * base / (8.0 * mean)  # flows/sec
    n = max(1, int(lam * cfg.duration_s * 1.05) + 16)
    gaps = rng.exponential(1.0 / lam, n)
    arrivals = np.cumsum(gaps)
    keep = arrivals < cfg.duration_s
    arrivals = arrivals[keep].astype(np.float32)
    n = len(arrivals)
    sizes = sampler(n)
    src = rng.integers(0, cfg.n_hosts, n).astype(np.int32)
    if cfg.inter_rack_only:
        # redraw dst until on a different leaf (vectorized rejection)
        dst = rng.integers(0, cfg.n_hosts, n).astype(np.int32)
        for _ in range(64):
            same = (src // cfg.hosts_per_leaf) == (dst // cfg.hosts_per_leaf)
            if not same.any():
                break
            dst[same] = rng.integers(0, cfg.n_hosts, int(same.sum())).astype(np.int32)
        if cfg.n_hosts > cfg.hosts_per_leaf:
            # deterministic fallback: shift any survivor of the rejection
            # loop to the same offset on the next leaf (never silently keep
            # an intra-rack pair — it would vanish from the fabric stats).
            # The shift moves the LEAF index, not the host index, so a
            # ragged final leaf (n_hosts % hosts_per_leaf != 0) can't wrap
            # a survivor back into its own rack; the clamp only engages
            # when the target is that ragged final leaf.
            hpl = cfg.hosts_per_leaf
            n_leaf = -(-cfg.n_hosts // hpl)
            same = (src // hpl) == (dst // hpl)
            shifted = ((dst // hpl + 1) % n_leaf) * hpl + dst % hpl
            shifted = np.minimum(shifted, cfg.n_hosts - 1)
            dst = np.where(same, shifted, dst).astype(np.int32)
    else:
        dst = rng.integers(0, cfg.n_hosts, n).astype(np.int32)
        dst = np.where(dst == src, (dst + 1) % cfg.n_hosts, dst).astype(np.int32)

    flow_id = np.arange(n, dtype=np.uint32) * np.uint32(2654435761) + np.uint32(cfg.seed)

    if cfg.max_flows is not None and n > cfg.max_flows:
        sizes, arrivals, src, dst, flow_id = (
            a[: cfg.max_flows] for a in (sizes, arrivals, src, dst, flow_id)
        )
        n = cfg.max_flows
    pad = 0
    if cfg.max_flows is not None and n < cfg.max_flows:
        pad = cfg.max_flows - n

    def padded(a, fill):
        return np.concatenate([a, np.full(pad, fill, a.dtype)]) if pad else a

    valid = padded(np.ones(n, bool), False)
    return Trace(
        sizes=padded(sizes, 1.0),
        arrivals=padded(arrivals, np.float32(1e30)),
        src=padded(src, 0),
        dst=padded(dst, 0),
        flow_id=padded(flow_id, 0),
        valid=valid,
    )


def _ecmp_steered_fids(src: np.ndarray, dst: np.ndarray, base_fid: np.ndarray,
                       target_path: np.ndarray, n_paths: int) -> np.ndarray:
    """Per-QP flow ids whose engine-side ECMP hash lands on the planned
    fabric path — the fluid-model analog of steering a RoCE QP's UDP source
    port so the fabric's five-tuple hash picks the path the planner chose
    (how PathTag-less deployments pin multipath today).  Mirrors
    ``engine.flow_constants``'s per-flow five-tuple (sport from
    ``fmix32(fid)``, dport 4791); ``tests/test_cosim.py`` pins the two
    against each other so they cannot drift silently.

    For each QP the candidate ids ``base + k * golden`` are hashed in one
    vectorized sweep and the first hit wins; a QP with no hit in the ~32x
    oversampled candidate set (probability ~(1-1/P)^(32P) ~ 1e-14) keeps
    its base id."""
    import jax.numpy as jnp

    from repro.core import hashing, routing

    K = int(min(32 * n_paths, 16384))
    ks = np.arange(K, dtype=np.uint32) * np.uint32(0x9E3779B1)
    cand = base_fid.astype(np.uint32)[:, None] + ks[None, :]  # [Q, K] wraps
    sport = jnp.uint32(0xB000) + (hashing.fmix32(jnp.asarray(cand))
                                  % jnp.uint32(0x3FFF))
    dport = jnp.full(cand.shape, 4791, jnp.uint32)
    p = routing.ecmp_paths(
        jnp.asarray(src, np.uint32)[:, None], jnp.asarray(dst, np.uint32)[:, None],
        sport, dport, n_paths)
    hit = np.asarray(p) == np.asarray(target_path, np.int32)[:, None]
    k = np.where(hit.any(axis=1), hit.argmax(axis=1), 0)
    return cand[np.arange(cand.shape[0]), k]


def collective_trace(
    plan,
    hosts: list[int] | np.ndarray,
    size_bytes: float,
    *,
    link_bw: float,
    start_s: float = 0.0,
    rounds: int | None = None,
    round_gap_s: float | None = None,
    seed: int = 0,
    steer_paths: int | None = None,
    steer_targets: np.ndarray | None = None,
) -> Trace:
    """AI-training traffic mode: the ring schedule of a grad-sync PathPlan
    (``repro.dist.collectives.PathPlan`` — duck-typed: anything with
    ``n_chunks``, ``directions`` and ``chunk_paths()``) rendered as a
    sweepable Trace.

    ``hosts`` are the ring members (e.g. one host per leaf — the pod
    gateways).  A chunked bidirectional ring all-reduce of ``size_bytes``
    per member runs ``2*(n-1)`` rounds; in every round each member sends
    one segment of each chunk to its ring neighbor in that chunk's
    direction.  The result is the paper's motivating pattern: a handful of
    huge, synchronized, long-lived flows between fixed host pairs — ECMP
    collapses them onto few fabric paths, SeqBalance's sub-flows spread
    them.  Each (chunk, ring member) pair keeps ONE flow id across all
    rounds — the persistent QP of that chunk-ring segment — so hash-based
    schemes pin it to one path for the whole collective (re-hashing per
    round would both reorder the chunk and accidentally load-balance the
    very hotspots this traffic mode exists to demonstrate).

    ``round_gap_s`` defaults to the segment serialization time at
    ``link_bw`` (the idealized bulk-synchronous cadence).

    ``steer_paths`` (= the topology's ``n_paths``) turns the plan into a
    BINDING route: each QP's flow id is chosen so the engine's ECMP
    five-tuple hash maps it onto its planned fabric path
    (``_ecmp_steered_fids`` — UDP-source-port steering in the fluid
    model).  The chunk -> path map supplies the ring DIRECTIONS; the
    steered fabric target is additionally diversified per member —
    member i's chunk-c QP rides active_path[(i * n_chunks + c) % n_active]
    — because on a 3-tier fabric a globally shared per-chunk path would
    funnel every member's chunk-c flow through one 100G agg-core link
    (n-fold overload by construction), while per-member spreading is
    exactly what per-QP source ports give a real deployment.  Quarantined
    paths are excluded from the spread, so the co-sim loop can actually
    route AROUND them — the whole Fig. 11 convergence story.  Without
    ``steer_paths`` the plan only shapes the traffic matrix and the
    fabric re-rolls paths by hash.

    ``steer_targets`` (int [n_chunks, n], needs ``steer_paths``) overrides
    the default spread with an EXPLICIT per-QP fabric target.  This is the
    in-epoch replanning hook (``dist.cosim``): the caller pins every
    surviving QP to exactly the target it had before a mid-collective
    fault — keeping its flow id, hence its path, hence its packet order —
    and re-steers only the QPs whose target died.  The default spread
    formula recomputes from the ACTIVE set, which shifts every QP's target
    when the set shrinks; that is fine between collectives but would be a
    mass reorder inside one.
    """
    hosts = np.asarray(hosts, np.int64)
    n = int(hosts.size)
    assert n >= 2, "a ring needs at least two members"
    n_chunks = int(plan.n_chunks)
    paths = tuple(plan.chunk_paths())
    dirs = tuple(int(plan.directions[p]) for p in paths)  # per-chunk ring dir
    seg_bytes = float(size_bytes) / (n * n_chunks)
    if round_gap_s is None:
        round_gap_s = seg_bytes * 8.0 / link_bw
    n_rounds = 2 * (n - 1) if rounds is None else int(rounds)

    base = (seed * 0x9E3779B9) & 0xFFFFFFFF
    fcells = int(getattr(plan, "flowcells", 1))
    if fcells > 1:
        # token-based flowcell splitting (RDMACell): each (chunk, member)
        # segment is cut into `fcells` cells on DISTINCT QPs, each steered
        # to its own path from plan.flowcell_paths()'s round-robin — the
        # rendered trace carries spray = straddled-path count so the
        # dataplane can charge the reordering cost.  Kept as a separate
        # branch so the fcells == 1 path below stays byte-identical to the
        # pre-flowcell construction (pinned by the sha-golden twins).
        return _flowcell_trace(
            plan, hosts, n, n_chunks, dirs, seg_bytes, round_gap_s, n_rounds,
            start_s, base, fcells, steer_paths, steer_targets)
    # one QP per (chunk, member), persistent across rounds
    qp_fid = np.array(
        [[((c * n + i) * 2654435761 + base) & 0xFFFFFFFF for i in range(n)]
         for c in range(n_chunks)], np.uint32)
    if steer_paths is not None:
        assert max(paths) < steer_paths, (paths, steer_paths)
        active = [p for p, dead in enumerate(plan.inactive)
                  if not dead and p < steer_paths] or [0]
        q_src = np.array([[hosts[i] for i in range(n)]
                          for c in range(n_chunks)], np.int64)
        q_dst = np.array([[hosts[(i + dirs[c]) % n] for i in range(n)]
                          for c in range(n_chunks)], np.int64)
        if steer_targets is not None:
            q_target = np.asarray(steer_targets, np.int32).reshape(n_chunks, n)
            assert int(q_target.max()) < steer_paths, (q_target, steer_paths)
        else:
            q_target = np.array(
                [[active[(i * n_chunks + c) % len(active)] for i in range(n)]
                 for c in range(n_chunks)], np.int32)
        qp_fid = _ecmp_steered_fids(
            q_src.reshape(-1), q_dst.reshape(-1), qp_fid.reshape(-1),
            q_target.reshape(-1), steer_paths).reshape(n_chunks, n)
    sizes, arrivals, src, dst, flow_id = [], [], [], [], []
    for r in range(n_rounds):
        t = start_s + r * round_gap_s
        for c, d in enumerate(dirs):
            for i in range(n):
                sizes.append(seg_bytes)
                arrivals.append(t)
                src.append(hosts[i])
                dst.append(hosts[(i + d) % n])
                flow_id.append(qp_fid[c, i])
    f = len(sizes)
    flow_id = np.asarray(flow_id, np.uint32)
    return Trace(
        sizes=np.asarray(sizes, np.float32),
        arrivals=np.asarray(arrivals, np.float32),
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        flow_id=flow_id,
        valid=np.ones(f, bool),
    )


def _flowcell_trace(plan, hosts, n, n_chunks, dirs, seg_bytes, round_gap_s,
                    n_rounds, start_s, base, fcells, steer_paths,
                    steer_targets) -> Trace:
    """Flowcell rendering of a collective: one QP per (chunk, member, cell),
    cell sizes ``seg_bytes / fcells`` (bytes per chunk conserved), cell j
    steered to the j-th active path after the chunk's own (the
    ``PathPlan.flowcell_paths`` round-robin, diversified per member exactly
    like the chunk-granularity default).  Every row carries
    ``spray = min(fcells, n_active)`` — the straddle count the dataplane's
    ``reorder_gbn_factor`` turns into a go-back-N amplification."""
    active = [p for p, dead in enumerate(plan.inactive) if not dead]
    if steer_paths is not None:
        active = [p for p in active if p < steer_paths]
    if not active:
        active = [0]
    A = len(active)
    spray_val = min(fcells, A)
    qp_fid = np.array(
        [[[((c * n + i) * 2654435761 + base + j * 0x85EBCA77) & 0xFFFFFFFF
           for j in range(fcells)] for i in range(n)] for c in range(n_chunks)],
        np.uint32)
    if steer_paths is not None:
        q_src = np.array([[[hosts[i]] * fcells for i in range(n)]
                          for c in range(n_chunks)], np.int64)
        q_dst = np.array([[[hosts[(i + dirs[c]) % n]] * fcells
                           for i in range(n)] for c in range(n_chunks)], np.int64)
        if steer_targets is not None:
            # in-epoch replanning: cell 0 keeps the EXPLICIT pinned target
            # (same five-tuple -> same path -> no reorder); later cells walk
            # the active paths from it.
            pinned = np.asarray(steer_targets, np.int32).reshape(n_chunks, n)
            assert int(pinned.max()) < steer_paths, (pinned, steer_paths)
            q_target = np.empty((n_chunks, n, fcells), np.int32)
            for c in range(n_chunks):
                for i in range(n):
                    p0 = int(pinned[c, i])
                    b = active.index(p0) if p0 in active else 0
                    q_target[c, i, 0] = p0
                    for j in range(1, fcells):
                        q_target[c, i, j] = active[(b + j) % A]
        else:
            q_target = np.array(
                [[[active[(i * n_chunks + c + j) % A] for j in range(fcells)]
                  for i in range(n)] for c in range(n_chunks)], np.int32)
        qp_fid = _ecmp_steered_fids(
            q_src.reshape(-1), q_dst.reshape(-1), qp_fid.reshape(-1),
            q_target.reshape(-1), steer_paths).reshape(n_chunks, n, fcells)
    cell_bytes = seg_bytes / fcells
    sizes, arrivals, src, dst, flow_id = [], [], [], [], []
    for r in range(n_rounds):
        t = start_s + r * round_gap_s
        for c, d in enumerate(dirs):
            for i in range(n):
                for j in range(fcells):
                    sizes.append(cell_bytes)
                    arrivals.append(t)
                    src.append(hosts[i])
                    dst.append(hosts[(i + d) % n])
                    flow_id.append(qp_fid[c, i, j])
    f = len(sizes)
    return Trace(
        sizes=np.asarray(sizes, np.float32),
        arrivals=np.asarray(arrivals, np.float32),
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        flow_id=np.asarray(flow_id, np.uint32),
        valid=np.ones(f, bool),
        spray=np.full(f, spray_val, np.int32),
    )


def merge_traces(*traces: Trace) -> Trace:
    """Concatenate traces into one (the engine sorts by arrival itself).

    The in-epoch replanning path (``dist.cosim``) renders a collective as
    two segments — rounds before the fault onset under the original plan,
    rounds after under the replanned one — and merges them into the single
    Trace the sweep runner consumes.  Flow ids are NOT remapped: a chunk
    whose path survived the replan keeps the same QP fid in both segments,
    which is exactly the no-reordering invariant (same five-tuple -> same
    fabric path before and after the cut)."""
    assert traces, "nothing to merge"
    return Trace(
        sizes=np.concatenate([t.sizes for t in traces]),
        arrivals=np.concatenate([t.arrivals for t in traces]),
        src=np.concatenate([t.src for t in traces]),
        dst=np.concatenate([t.dst for t in traces]),
        flow_id=np.concatenate([t.flow_id for t in traces]),
        valid=np.concatenate([t.valid for t in traces]),
        spray=np.concatenate([t.spray for t in traces]),
    )


def permanent_senders_trace(
    pairs: list[tuple[int, int]], start_times: list[float], size_bytes: float
) -> Trace:
    """Fig. 10/11 scenario: long-lived full-rate flows (ib_write_bw), one
    activated per interval."""
    n = len(pairs)
    return Trace(
        sizes=np.full(n, size_bytes, np.float32),
        arrivals=np.asarray(start_times, np.float32),
        src=np.asarray([p[0] for p in pairs], np.int32),
        dst=np.asarray([p[1] for p in pairs], np.int32),
        flow_id=np.arange(n, dtype=np.uint32) * np.uint32(0x9E3779B9),
        valid=np.ones(n, bool),
    )
