"""repro.obs — the observability plane (DESIGN.md §16).

Three layers, one package:

  * ``recorder``     — traced in-sim ring buffer of per-chunk summaries
    (carried through ``compact.run_core``; zero rebuilds across epochs,
    ``record=None`` bit-identical to no recorder at all);
  * ``flightlog``    — schema-versioned JSONL control-plane event log
    (journal schema v2, ``journal: "flight"``), fed by ``dist/cosim.py``,
    ``netsim/faults.py`` activations, and ``netsim/sweep.py`` counters;
  * ``trace_export`` / ``features`` — perfetto Chrome-trace exporter and
    the [epoch, uplink, feature] matrix for the predictive planner.

``runmeta()`` stamps records (bench JSON sections, flight-log headers)
with run id / git sha / host / device count so perf trajectories are
attributable across machines.
"""
from __future__ import annotations

import functools
import os
import socket
import subprocess
import time
import uuid

from repro.obs.flightlog import (  # noqa: F401
    SCHEMA_VERSION, FlightLog, FlightLogError, read_flight,
)
from repro.obs.recorder import (  # noqa: F401
    META_FIELDS, RecordSpec, RingState, drain, epoch_summary, meta_fields,
    record_chunk, ring_init,
)

#: one run id per process: every runmeta()/FlightLog/bench section written
#: by this process carries the same id, which is what makes them joinable
_RUN_ID = uuid.uuid4().hex[:12]


@functools.lru_cache(maxsize=1)
def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def runmeta() -> dict:
    """Provenance stamp: run id, git sha, host, jax device count/backend,
    UTC wall clock.  Cheap after the first call (sha is cached; jax is
    already initialized by any caller that simulates)."""
    try:
        import jax

        n_devices = jax.local_device_count()
        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax always present in this repo
        n_devices, backend = 0, "unknown"
    return dict(
        run_id=_RUN_ID,
        git_sha=_git_sha(),
        host=socket.gethostname(),
        n_devices=int(n_devices),
        backend=backend,
        time_utc=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    )
