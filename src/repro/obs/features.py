"""Feature extraction: flight log -> [epoch, uplink, feature] arrays.

The ROADMAP's predictive planner (arXiv 2506.08132) needs per-epoch,
per-uplink congestion features to forecast the next hotspot before
``LinkHealth`` reacts to it.  ``epoch_matrix`` is that data factory's
output format: it reads the ``epoch`` events of a flight log (their
``insim`` summaries come from the in-sim ring recorder) and lays them out
as a dense float matrix plus the epoch/feature axes — ready to stack
across ``run_cosim_grid`` rollouts into a training set.

Per-uplink features come from ``insim["uplink"]``; epoch-global features
(queue max, CNP total, fast-forward occupancy, plan churn, quarantine
count) are broadcast across the uplink axis so a single matrix carries
both views.
"""
from __future__ import annotations

import numpy as np

#: default feature axis, in matrix column order
FEATURES = (
    "offered_mean_gbps",  # per-uplink
    "offered_max_gbps",  # per-uplink
    "cap_mean_gbps",  # per-uplink
    "util_mean",  # per-uplink
    "util_max",  # per-uplink
    "queue_max_bytes",  # epoch-global, broadcast
    "cnp_pkts",  # epoch-global, broadcast
    "ff_fraction",  # epoch-global, broadcast
    "plan_churn",  # epoch-global, broadcast
    "quarantined_n",  # epoch-global, broadcast
)


def epoch_matrix(flight, *, features: tuple = FEATURES) -> dict:
    """Build the [E, U, F] feature matrix from a flight log.

    ``flight`` is a path (read via ``flightlog.read_flight``) or an
    already-loaded ``(header, records)`` pair.  Only ``epoch`` events that
    carry an ``insim`` summary contribute (recording must have been on);
    raises ``ValueError`` when none do or uplink counts disagree.

    Returns ``dict(epochs, features, matrix)`` with ``matrix`` a float64
    ndarray of shape ``[len(epochs), U, len(features)]``."""
    from repro.obs.flightlog import read_flight

    if isinstance(flight, (tuple, list)):
        _, records = flight
    else:
        _, records = read_flight(flight)
    rows = [r for r in records
            if r.get("kind") == "epoch" and (r.get("insim") or {}).get("uplink")]
    if not rows:
        raise ValueError("flight log has no epoch events with in-sim "
                         "summaries (was recording enabled?)")
    U = len(rows[0]["insim"]["uplink"]["offered_mean_gbps"])
    mat = np.zeros((len(rows), U, len(features)), np.float64)
    for e, rec in enumerate(rows):
        ins = rec["insim"]
        upl = ins["uplink"]
        if len(upl["offered_mean_gbps"]) != U:
            raise ValueError(f"epoch {rec.get('epoch')}: uplink count "
                             f"{len(upl['offered_mean_gbps'])} != {U}")
        for fi, name in enumerate(features):
            if name in upl:
                mat[e, :, fi] = np.asarray(upl[name], np.float64)
            elif name == "ff_fraction":
                mat[e, :, fi] = (ins.get("ff_steps", 0)
                                 / max(ins.get("steps_covered", 0), 1))
            elif name == "quarantined_n":
                mat[e, :, fi] = len(rec.get("quarantined") or ())
            elif name in ins:
                mat[e, :, fi] = float(ins[name])
            else:
                mat[e, :, fi] = float(rec.get(name, 0.0))
    return dict(epochs=[r.get("epoch", e) for e, r in enumerate(rows)],
                features=list(features), matrix=mat)
