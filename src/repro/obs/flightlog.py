"""Structured control-plane flight log (DESIGN.md §16).

One JSONL file per run, written next to the co-sim epoch journal and
sharing its schema version (``cosim.JOURNAL_SCHEMA_VERSION == 2`` — the
flight log is journal schema v2 with ``journal: "flight"``, not a second
schema).  Line 0 is the header (run id + ``obs.runmeta()`` provenance);
every following line is one event ``{"kind": ..., "ts_s": <unix s>, ...}``.
Counters, gauges, and histograms are plain fields on typed events rather
than a separate metric taxonomy — the consumers (``obs.trace_export``,
``obs.features.epoch_matrix``, ``scripts/obs_report.py``) read kinds:

  * ``campaign``  — fault-campaign / scenario description at run start
  * ``epoch``     — one per planning epoch: wall-clock span, FCT stats,
    plan version/churn, quarantine + watchdog + telemetry-channel state,
    sweep compile/retry counters, hot uplinks, fault activations, and the
    drained in-sim ring summary under ``insim``
  * ``run_end``   — convergence summary + totals
  * ``profile``   — benchmarks/run.py --profile phase rows (min/mean/std)
  * ``counter``   — generic named counter sample

Writes are line-buffered and flushed per event; ``read_flight`` tolerates
a torn tail (a crashed run's last partial line is dropped, same contract
as the epoch journal) and refuses other schema versions loudly
(``FlightLogError``).
"""
from __future__ import annotations

import json
import time

#: Must track cosim.JOURNAL_SCHEMA_VERSION — asserted in tests/test_obs.py.
SCHEMA_VERSION = 2


class FlightLogError(RuntimeError):
    """Flight-log file unreadable or from an incompatible schema."""


class FlightLog:
    """Append-only JSONL event writer.  ``close()`` is idempotent."""

    def __init__(self, path, *, meta: dict | None = None,
                 run_id: str | None = None):
        from repro import obs  # deferred: obs/__init__ imports this module

        self.path = str(path)
        rm = obs.runmeta()
        self.run_id = run_id or rm["run_id"]
        self._fh = open(self.path, "a")
        header = {"journal": "flight", "schema_version": SCHEMA_VERSION,
                  "run_id": self.run_id, "runmeta": rm}
        if meta:
            header["meta"] = meta
        self._write(header)

    def _write(self, obj: dict):
        if self._fh is None:
            return
        self._fh.write(json.dumps(obj) + "\n")
        self._fh.flush()

    def event(self, kind: str, **fields):
        """One event line.  ``ts_s`` is stamped here unless the caller
        passes its own (e.g. an epoch's true start time)."""
        rec = {"kind": kind}
        rec.setdefault("ts_s", fields.pop("ts_s", time.time()))
        rec.update(fields)
        self._write(rec)

    def counter(self, name: str, value, **fields):
        self.event("counter", name=name, value=value, **fields)

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_flight(path) -> tuple[dict, list]:
    """(header, events) from a flight-log file.

    Skips blank lines, drops a torn tail, tolerates appended restart
    headers (same run id appending after a resume), and raises
    ``FlightLogError`` on a missing header or a schema-version mismatch."""
    header = None
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail: a crash mid-write loses only that line
            if obj.get("journal") == "flight":
                if obj.get("schema_version") != SCHEMA_VERSION:
                    raise FlightLogError(
                        f"{path}: flight schema v{obj.get('schema_version')} "
                        f"!= v{SCHEMA_VERSION} (refusing to guess)")
                if header is None:
                    header = obj
                continue  # restart header mid-file: keep reading events
            if header is None:
                raise FlightLogError(f"{path}: first line is not a flight "
                                     "header")
            records.append(obj)
    if header is None:
        raise FlightLogError(f"{path}: empty or headerless flight log")
    return header, records
