"""In-sim flight recorder: a traced, fixed-shape ring buffer of per-chunk
summaries carried through ``compact.run_core``'s chunk loop (DESIGN.md §16).

The compact engine runs the horizon as K-step ``lax.scan`` chunks inside an
early-exit ``while_loop``; per-``dt``-step traces at paper scale are far too
large to keep, but one summary row per *chunk* is nearly free: the scan
already materializes the chunk's output slab, so the recorder just reduces
it (max/mean/sum) plus a handful of state statistics (active sub-flows,
DCQCN rate quantiles, per-uplink offered-vs-capacity) into a fixed-shape
ring written with ``dynamic_update_slice`` at ``count % R``.  Fixed shapes
mean the ring joins the executable-cache key exactly like the traced
capacity operand (PR 5): one extra compiled program per ``RecordSpec``,
ZERO rebuilds across epochs — gated by ``scripts/check_bench.py --obs``.

All gating happens at Python trace time: ``record=None`` traces the
identical program as before recording existed (bit-identical results,
pinned by the sha goldens in tests/test_obs.py).

Quantiles are sort-based rank statistics (``sort`` + gather at
``(n_active - 1) * q``), not ``nanpercentile`` — deterministic, no data-
dependent shapes, exact on the active sub-flow population.

Host-side, ``drain`` unrolls the ring into chronological order (the newest
``R`` chunks survive a wraparound; the exact boundary chunk is included —
tested) and ``epoch_summary`` reduces it to the JSON-able per-epoch record
the flight log and ``obs.features.epoch_matrix`` consume.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RecordSpec:
    """Recorder knobs.  Frozen + hashable: the spec joins the sweep
    executable-cache key (netsim/sweep.py), so two runs with the same spec
    share one compiled program."""

    ring_chunks: int = 64  # R: per-chunk summary rows retained (newest win)
    quantiles: tuple = (0.1, 0.5, 0.9)  # DCQCN rc rank quantiles


#: scalar summary columns of ``RingState.meta`` (before the per-spec
#: ``rc_q*`` quantile columns appended by ``meta_fields``)
META_FIELDS = (
    "step0",  # first dt step of the chunk
    "steps",  # chunk length in dt steps
    "ff",  # 1.0 if the chunk was covered by a quiescence fast-forward
    "queue_max",  # max over the chunk of the per-step max queue (bytes)
    "queue_mean",  # mean over the chunk of the per-step max queue (bytes)
    "cnp_pkts",  # expected congestion packets generated in the chunk
    "goodput_mean",  # mean total delivered rate over the chunk (bit/s)
    "active_subflows",  # active sub-flows at the chunk boundary
)


def meta_fields(spec: RecordSpec) -> tuple:
    return META_FIELDS + tuple(
        f"rc_q{int(round(q * 100))}" for q in spec.quantiles)


class RingState(NamedTuple):
    """Fixed-shape recorder state (a pytree: vmap/pmap batch it like any
    other sim output)."""

    meta: jax.Array  # f32[R, M] per-chunk scalar summaries
    uplink: jax.Array  # f32[R, U, 2] per-uplink (offered, capacity) bit/s
    count: jax.Array  # i32[] chunks written so far (monotonic, may exceed R)


def ring_init(spec: RecordSpec, n_uplinks: int) -> RingState:
    R = int(spec.ring_chunks)
    return RingState(
        meta=jnp.zeros((R, len(meta_fields(spec))), jnp.float32),
        uplink=jnp.zeros((R, int(n_uplinks), 2), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def record_chunk(spec: RecordSpec, ring: RingState, *, step0, steps, ff,
                 queue_max, queue_mean, cnp, goodput, offered, cap, rc,
                 active) -> RingState:
    """Append one chunk-summary row (traced; fixed shapes only).

    ``offered``/``cap`` are f32[U] per-uplink rates at chunk granularity;
    ``rc`` f32[W, N] DCQCN rates and ``active`` bool[W, N] the live mask at
    the chunk boundary; everything else is scalar."""
    f32 = jnp.float32
    n_act = jnp.sum(active.astype(jnp.int32))
    n_act_f = n_act.astype(f32)
    vals = jnp.sort(jnp.where(active, rc, jnp.inf).ravel())
    size = int(vals.shape[0])
    qs = []
    for q in spec.quantiles:
        idx = jnp.clip(((n_act_f - 1.0) * f32(q)).astype(jnp.int32),
                       0, size - 1)
        qs.append(jnp.where(n_act > 0, vals[idx], f32(0.0)))
    row = jnp.stack([
        jnp.asarray(step0).astype(f32), f32(steps), f32(ff),
        jnp.asarray(queue_max).astype(f32),
        jnp.asarray(queue_mean).astype(f32),
        jnp.asarray(cnp).astype(f32),
        jnp.asarray(goodput).astype(f32),
        n_act_f,
    ] + qs)
    slot = ring.count % spec.ring_chunks
    meta = jax.lax.dynamic_update_slice(ring.meta, row[None], (slot, 0))
    up = jnp.stack([jnp.asarray(offered).astype(f32),
                    jnp.asarray(cap).astype(f32)], axis=-1)  # [U, 2]
    uplink = jax.lax.dynamic_update_slice(ring.uplink, up[None], (slot, 0, 0))
    return RingState(meta=meta, uplink=uplink, count=ring.count + 1)


def drain(spec: RecordSpec, ring: RingState) -> dict:
    """Host-side: unroll one sim's ring into chronological order.

    After ``count`` writes the oldest retained chunk sits at slot
    ``count % R`` (write ``i`` lands at ``i % R``), so the chronological
    index is ``(count % R + arange(R)) % R`` — the newest ``R`` chunks
    survive, boundary chunk included."""
    R = int(spec.ring_chunks)
    count = int(np.asarray(ring.count))
    n = min(count, R)
    meta = np.asarray(ring.meta)
    uplink = np.asarray(ring.uplink)
    idx = np.arange(n) if count <= R else (count % R + np.arange(R)) % R
    return dict(
        fields=list(meta_fields(spec)),
        meta=meta[idx],
        uplink=uplink[idx],
        chunks_recorded=count,
        chunks_kept=int(n),
    )


def epoch_summary(spec: RecordSpec, drained: dict) -> dict:
    """Reduce a drained ring to the JSON-able per-epoch record the flight
    log stores (``EpochRecord.insim``): chunk-weighted scalar aggregates,
    per-uplink offered/capacity/utilization vectors, and the raw per-chunk
    table (R rows at most — small by construction)."""
    meta = np.asarray(drained["meta"], np.float64)
    uplink = np.asarray(drained["uplink"], np.float64)
    fields = list(drained["fields"])
    out = dict(schema="insim_v1",
               chunks_recorded=int(drained["chunks_recorded"]),
               chunks_kept=int(drained["chunks_kept"]))
    if meta.shape[0] == 0:
        return out
    col = {f: meta[:, i] for i, f in enumerate(fields)}
    steps = col["steps"]
    w = steps / max(float(steps.sum()), 1e-9)  # chunk-length weights
    offered = uplink[:, :, 0]
    cap = np.maximum(uplink[:, :, 1], 1e-9)
    util = np.minimum(offered / cap, 1e6)  # dead links read huge, not inf
    rnd = lambda a: np.round(np.asarray(a, np.float64), 6).tolist()
    out.update(
        steps_covered=int(steps.sum()),
        ff_chunks=int(col["ff"].sum()),
        ff_steps=int((col["ff"] * steps).sum()),
        queue_max_bytes=float(col["queue_max"].max()),
        queue_mean_bytes=float((col["queue_mean"] * w).sum()),
        cnp_pkts=float(col["cnp_pkts"].sum()),
        goodput_mean_bps=float((col["goodput_mean"] * w).sum()),
        active_subflows_max=float(col["active_subflows"].max()),
        uplink=dict(
            offered_mean_gbps=rnd((offered * w[:, None]).sum(0) / 1e9),
            offered_max_gbps=rnd(offered.max(0) / 1e9),
            cap_mean_gbps=rnd((cap * w[:, None]).sum(0) / 1e9),
            util_mean=rnd((util * w[:, None]).sum(0)),
            util_max=rnd(util.max(0)),
        ),
        chunks={f: rnd(col[f]) for f in fields},
    )
    return out
