"""Chrome trace-event exporter: flight log -> perfetto-loadable JSON.

Lays a whole co-sim run on one timeline (open the output at
https://ui.perfetto.dev or chrome://tracing):

  * ``epochs`` track    — one "X" span per planning epoch (wall-clock),
    args carrying plan version/churn, builds, FCT stats;
  * ``faults`` track    — one span per active fault per epoch (kind +
    parameters in args), so brownouts/flaps line up under the epochs they
    perturb;
  * ``control`` track   — "C" counter series (plan_churn, quarantined_n,
    new_builds, ff_steps, reports_admitted) perfetto renders as graphs,
    plus instant markers for safe-mode entry/exit;
  * ``in-sim`` track    — the recorder's fast-forwarded chunks placed
    *proportionally* inside their epoch's wall-clock span (sim step ->
    fraction of the epoch), making quiescence occupancy visible at a
    glance.

Timestamps are microseconds relative to the first epoch start (the
trace-event format's native unit).  CLI:

    PYTHONPATH=src python -m repro.obs.trace_export flight.jsonl trace.json
"""
from __future__ import annotations

import json

_PID = 1
_TID_EPOCH, _TID_FAULT, _TID_CTRL, _TID_INSIM = 1, 2, 3, 4

#: epoch-record fields exported as "C" counter series on the control track
_COUNTERS = ("plan_churn", "quarantined_n", "new_builds", "ff_steps",
             "reports_admitted")


def chrome_trace(header: dict, records: list) -> dict:
    """Build the trace-event dict (``{"traceEvents": [...]}``) from a
    parsed flight log.  Pure function of the records — no I/O."""
    ev = [
        {"ph": "M", "pid": _PID, "name": "process_name",
         "args": {"name": f"cosim {header.get('run_id', '?')}"}},
    ]
    for tid, name in ((_TID_EPOCH, "epochs"), (_TID_FAULT, "faults"),
                      (_TID_CTRL, "control"), (_TID_INSIM, "in-sim")):
        ev.append({"ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
                   "args": {"name": name}})

    epochs = [r for r in records if r.get("kind") == "epoch"]
    if not epochs:
        return {"traceEvents": ev, "displayTimeUnit": "ms"}
    t_base = min(r.get("t0_s", r.get("ts_s", 0.0)) for r in epochs)

    def us(t):
        return round((t - t_base) * 1e6, 1)

    prev_safe = False
    for rec in epochs:
        t0 = rec.get("t0_s", rec.get("ts_s", t_base))
        dur_us = max(float(rec.get("dur_s", 0.0)) * 1e6, 1.0)
        args = {k: rec[k] for k in
                ("plan_version", "plan_churn", "new_builds", "safe_mode",
                 "fct_p50_us", "fct_p99_us", "completion", "quarantined")
                if k in rec}
        ev.append({"ph": "X", "pid": _PID, "tid": _TID_EPOCH,
                   "name": f"epoch {rec.get('epoch')}", "ts": us(t0),
                   "dur": dur_us, "args": args})

        for cname in _COUNTERS:
            if cname == "quarantined_n":
                val = len(rec.get("quarantined") or ())
            elif cname == "reports_admitted":
                val = (rec.get("reports") or {}).get("admitted", -1)
                if val < 0:
                    continue
            else:
                val = rec.get(cname)
                if val is None:
                    continue
            ev.append({"ph": "C", "pid": _PID, "tid": _TID_CTRL,
                       "name": cname, "ts": us(t0), "args": {cname: val}})

        safe = bool(rec.get("safe_mode"))
        if safe != prev_safe:
            ev.append({"ph": "i", "pid": _PID, "tid": _TID_CTRL, "s": "p",
                       "name": "safe-mode " + ("enter" if safe else "exit"),
                       "ts": us(t0)})
            prev_safe = safe

        for f in rec.get("faults") or ():
            ev.append({"ph": "X", "pid": _PID, "tid": _TID_FAULT,
                       "name": f.get("kind", "fault"), "ts": us(t0),
                       "dur": dur_us, "args": f})

        ins = rec.get("insim") or {}
        chunks = ins.get("chunks") or {}
        n_steps = rec.get("n_steps") or 0
        if chunks.get("step0") and n_steps:
            # sim step -> fraction of the epoch's wall-clock span
            scale = dur_us / n_steps
            for s0, stp, ff in zip(chunks["step0"], chunks["steps"],
                                   chunks["ff"]):
                if ff:
                    ev.append({"ph": "X", "pid": _PID, "tid": _TID_INSIM,
                               "name": "fast-forward",
                               "ts": us(t0) + round(s0 * scale, 1),
                               "dur": max(round(stp * scale, 1), 0.1),
                               "args": {"step0": int(s0), "steps": int(stp)}})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def export_chrome_trace(flight_path, out_path) -> dict:
    """Read a flight log, write the Chrome trace JSON, return the trace."""
    from repro.obs.flightlog import read_flight

    header, records = read_flight(flight_path)
    trace = chrome_trace(header, records)
    with open(out_path, "w") as fh:
        json.dump(trace, fh)
    return trace


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="export a flight log as a perfetto-loadable Chrome "
                    "trace-event JSON")
    ap.add_argument("flight", help="flight-log JSONL path")
    ap.add_argument("out", help="output trace JSON path")
    args = ap.parse_args(argv)
    trace = export_chrome_trace(args.flight, args.out)
    print(f"wrote {len(trace['traceEvents'])} events -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
