"""Batched serving engine: fixed-slot continuous batching over the
prefill/decode step functions.

Requests are admitted into ``batch_size`` slots; every engine tick runs one
decode step for all active slots (one compiled program, no reshapes —
finished slots keep decoding into a scratch position and are masked out,
the standard TPU serving pattern).  Prefill runs per admission batch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # i32[prompt_len]
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 stop_token: int | None = None):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.stop_token = stop_token
        self.cache = None
        self.active: list[Request | None] = [None] * batch_size
        self.remaining = np.zeros(batch_size, np.int64)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, self.cfg, t, c), static_argnums=()
        )

    def admit(self, requests: list[Request]):
        """Admit a full batch (prefill).  Slot-aligned prompts are padded to
        the longest prompt; shorter prompts left-pad with token 1."""
        assert len(requests) <= self.B
        L = max(len(r.prompt) for r in requests)
        toks = np.ones((self.B, L), np.int32)
        for i, r in enumerate(requests):
            toks[i, L - len(r.prompt):] = r.prompt
            self.active[i] = r
            self.remaining[i] = r.max_new_tokens
        batch = {"tokens": jnp.asarray(toks)}
        logits, self.cache = model.prefill(self.params, self.cfg, batch, self.max_len)
        self._next = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for i, r in enumerate(requests):
            r.out_tokens.append(int(self._next[i, 0]))
            self.remaining[i] -= 1

    def step(self) -> int:
        """One decode tick for every active slot; returns #active."""
        logits, self.cache = self._decode(self.params, self._next, self.cache)
        self._next = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        n_active = 0
        host_next = np.asarray(self._next)
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            tok = int(host_next[i, 0])
            r.out_tokens.append(tok)
            self.remaining[i] -= 1
            if self.remaining[i] <= 0 or (self.stop_token is not None and tok == self.stop_token):
                r.done = True
            else:
                n_active += 1
        return n_active

    def run(self) -> list[Request]:
        while self.step() > 0:
            pass
        return [r for r in self.active if r is not None]
