"""Fault-tolerant checkpointing: atomic, content-hashed, async-capable.

Layout: <dir>/step_<n>/  { manifest.json, <leaf-id>.npy ... }
Writes go to a tmp dir and are renamed into place only after fsync — a
crash mid-save never corrupts the latest valid checkpoint.  Each leaf
records a SHA-256 in the manifest; restore verifies integrity before
handing weights back (bit-rot / torn-write detection at 1000-node scale).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _leaf_id(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_").replace("'", "").replace("[", ".").replace(
        "]", ""
    ).strip(".")


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Checkpoint a pytree.  With blocking=False the serialization happens
    on a daemon thread (straggler mitigation: the train loop never stalls
    on I/O); the atomic rename still guarantees consistency."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

    def work():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        # unique tmp per writer: an async save and a final blocking save of
        # the same step must never share a staging dir (first one wins)
        tmp = final + f".tmp.{os.getpid()}.{threading.get_ident()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for path, leaf in jax.tree_util.tree_flatten_with_path(host_tree)[0]:
            lid = _leaf_id(path)
            arr = np.asarray(leaf)
            fn = os.path.join(tmp, lid + ".npy")
            np.save(fn, arr)
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            manifest["leaves"][lid] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype), "sha256": h,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):  # another writer already landed this step
            shutil.rmtree(tmp)
            return
        os.rename(tmp, final)

    if blocking:
        work()
        return None
    t = threading.Thread(target=work, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and ".tmp" not in d
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` with integrity checks."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def load(path, like):
        lid = _leaf_id(path)
        meta = manifest["leaves"][lid]
        arr = np.load(os.path.join(d, lid + ".npy"))
        h = hashlib.sha256(arr.tobytes()).hexdigest()
        if h != meta["sha256"]:
            raise IOError(f"checkpoint integrity failure for leaf {lid}")
        if list(arr.shape) != list(np.shape(like)):
            raise ValueError(f"shape mismatch for {lid}: {arr.shape} vs {np.shape(like)}")
        return arr

    return jax.tree_util.tree_map_with_path(load, like_tree)
