"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state mirrors the parameter pytree, so whatever sharding the
params carry, the moments inherit it (ZeRO-1/2-equivalent under FSDP
sharding rules — no replicated optimizer state anywhere).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z, nu=jax.tree.map(jnp.zeros_like, params))


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {"grad_norm": gn, "lr": lr}
