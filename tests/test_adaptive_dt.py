"""Event-driven adaptive dt (DESIGN.md §15): seeded-twin bit-identity of
the fixed-dt path, adaptive-vs-fixed tolerance on the sparse collective
workload, the chunk/event-grid planner, DCQCN closed-form fast-forward,
and the executable-cache build-count contract."""
import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.netsim import compact, dcqcn, engine, faults, profile, sweep, \
    topology, workloads


# ------------------------------------------------- seeded-twin goldens
# Captured on the PR 7 tree (before any adaptive-dt code existed): the
# fig12-style sweep and the killed-spine co-sim must reproduce these
# EXACTLY with adaptive=False — the fixed-dt step loop is untouched.
FIG12_GOLD = {
    "seqbalance": ("97c5e5a8c9da4589", 78.61827087402344, 1076029.875),
    "ecmp": ("1ee9c2ede7c595b6", 75.699951171875, 473117.84375),
    "letflow": ("1ee9c2ede7c595b6", 75.699951171875, 473117.84375),
}
COSIM_GOLD = dict(
    p99=[8.999995770864189e-05, 0.0019099999917671084,
         0.0019099999917671084, 8.999995770864189e-05],
    p50=[4.999998782295734e-05, 0.0003299999807495624,
         0.0003599999472498894, 4.999998782295734e-05],
    quarantined=[(), (), (2,), (2,)],
    conv=3,
)


def _fig12_trace(topo):
    fabric = topo.n_leaf * topo.n_paths * 100e9
    return workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=0.8, duration_s=2.5e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=1,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=fabric))


@pytest.mark.parametrize("scheme", sorted(FIG12_GOLD))
def test_fixed_dt_bit_identical_fig12(scheme):
    topo = topology.sim_2tier()
    cfg = engine.SimConfig(scheme=scheme, duration_s=10e-3,
                           uplink_sample_every=10)
    res, _ = sweep.run_one(topo, cfg, _fig12_trace(topo))
    f = np.asarray(res.finish)
    fin = f[np.isfinite(f)]
    sha, fsum, cnp = FIG12_GOLD[scheme]
    assert hashlib.sha1(f.tobytes()).hexdigest()[:16] == sha
    assert float(fin.sum()) == fsum
    assert float(res.cnp_pkts) == cnp


def test_fixed_dt_bit_identical_cosim():
    from repro.dist import cosim

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    hosts = cosim.ring_hosts(topo, 8)
    h = cosim.run_cosim(
        topo, hosts, 4e6, scheme="seqbalance", epochs=4, phi_steps=2,
        n_chunks=4, seed=0,
        faults=(cosim.kill_spine(topo, 2, epoch=1, recover_epoch=3),))
    assert [r.fct_p99_s for r in h.records] == COSIM_GOLD["p99"]
    assert [r.fct_p50_s for r in h.records] == COSIM_GOLD["p50"]
    assert [r.quarantined for r in h.records] == COSIM_GOLD["quarantined"]
    assert h.convergence_epoch(1) == COSIM_GOLD["conv"]
    assert all(r.ff_steps == 0 for r in h.records)  # adaptive off


# ------------------------------------- adaptive vs fixed-dt (tolerance)
def _collective(topo, gap=800e-6, size=4e6, seed=0):
    from repro.dist import collectives, cosim

    plan = collectives.PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    hosts = cosim.ring_hosts(topo, 8)
    return workloads.collective_trace(plan, hosts, size, link_bw=100e9,
                                      round_gap_s=gap, seed=seed,
                                      steer_paths=topo.n_paths)


def _twin(topo, cfg, trace):
    res_f, _ = sweep.run_one(topo, cfg, trace)
    res_a, _ = sweep.run_one(topo, dataclasses.replace(cfg, adaptive=True),
                             trace)
    return res_f, res_a


def test_adaptive_fast_forwards_sparse_collective():
    """Compute gaps between all-reduce rounds are quiescent: the adaptive
    engine must skip them in closed form (ff_steps > 0) and still land
    every finish time and CNP count exactly."""
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=14e-3,
                           uplink_sample_every=10)
    res_f, res_a = _twin(topo, cfg, _collective(topo))
    assert int(res_a.ff_steps) > 0
    assert int(res_f.ff_steps) == 0
    assert np.array_equal(np.asarray(res_f.finish), np.asarray(res_a.finish))
    assert float(res_f.cnp_pkts) == float(res_a.cnp_pkts)


def test_adaptive_dense_trace_is_exact_noop():
    """Event-dense Poisson traffic: every chunk holds arrivals/finishes,
    so the predicate must never fire and the outputs stay bit-identical
    (same executable semantics, different program)."""
    topo = topology.leaf_spine(2, 4, 4, 100e9)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=0.6, duration_s=1.2e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=0,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=2 * 4 * 100e9))
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=4e-3,
                           uplink_sample_every=10)
    res_f, res_a = _twin(topo, cfg, trace)
    assert np.array_equal(np.asarray(res_f.finish), np.asarray(res_a.finish))
    assert float(res_f.cnp_pkts) == float(res_a.cnp_pkts)


def test_adaptive_uplink_outputs_match():
    """The fast-forward path emits its uplink slab analytically at sample
    granularity; window averages of a frozen cascade must equal the
    scanned averages."""
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=14e-3,
                           uplink_sample_every=10)
    trace = _collective(topo)
    (_, outs_f), (_, outs_a) = (sweep.run_one(topo, cfg, trace),
                                sweep.run_one(
                                    topo,
                                    dataclasses.replace(cfg, adaptive=True),
                                    trace))
    uf, ua = np.asarray(outs_f.uplink_load), np.asarray(outs_a.uplink_load)
    assert uf.shape == ua.shape
    np.testing.assert_allclose(ua, uf, rtol=1e-5, atol=1.0)
    np.testing.assert_allclose(np.asarray(outs_a.max_queue),
                               np.asarray(outs_f.max_queue),
                               rtol=1e-5, atol=1.0)


def test_adaptive_property_delivered_bytes_conserved():
    """Hypothesis sweep over gap/size/seed: adaptive and fixed dt finish
    the same flows, conserve total delivered bytes exactly, and every
    per-flow completion diverges by at most one dt step (the closed-form
    linear decrement vs the iterated f32 sum)."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=14e-3,
                           uplink_sample_every=10)

    @settings(max_examples=4, deadline=None)
    @given(gap=st.sampled_from([400e-6, 800e-6, 1200e-6]),
           size=st.sampled_from([2e6, 4e6, 6e6]),
           seed=st.integers(min_value=0, max_value=3))
    def prop(gap, size, seed):
        trace = _collective(topo, gap=gap, size=size, seed=seed)
        res_f, res_a = _twin(topo, cfg, trace)
        f = np.asarray(res_f.finish)
        a = np.asarray(res_a.finish)
        valid = np.asarray(trace.valid, bool)
        done_f = np.isfinite(f) & valid
        done_a = np.isfinite(a) & valid
        assert np.array_equal(done_f, done_a)
        sizes = np.asarray(trace.sizes)
        assert float(sizes[done_f].sum()) == float(sizes[done_a].sum())
        assert np.all(np.abs(f[done_f] - a[done_f]) <= cfg.dt + 1e-9)

    prop()


# --------------------------------------------- planner: chunks + grid
def test_plan_chunks_tail_folds_away():
    """K must be a sample-window multiple, and a tail (second compiled
    scan body) may only survive when the sample window itself does not
    divide the horizon."""
    for chunk, s, n in [(32, 10, 1000), (32, 1, 1000), (20, 10, 1400),
                        (32, 8, 1000), (7, 3, 21), (32, 10, 995),
                        (16, 5, 1005), (32, 32, 64), (1, 1, 7)]:
        cfg = engine.SimConfig(chunk_steps=chunk, uplink_sample_every=s)
        K, n_chunks, tail = compact.plan_chunks(cfg, n)
        assert K % s == 0 and K >= 1
        assert K * n_chunks + tail == n
        if n % s == 0:
            assert tail == 0, (chunk, s, n, K, tail)


def test_event_grid_boundaries():
    cfg = engine.SimConfig(dt=10e-6, uplink_sample_every=10)
    arrivals = np.array([0.0, 95e-6, 1e-3, 2.0])  # last beyond horizon
    grid = compact.event_grid(cfg, 500, arrivals=arrivals,
                              valid=np.array([1, 1, 1, 1], bool),
                              cap_seg_steps=125)
    assert grid[0] == 0 and grid[-1] == 500
    for step in (10, 100, 125, 250):  # arrival ceils + seg + sample edges
        assert step in grid
    assert np.all(np.diff(grid) > 0)


def test_seg_steps_chunk_alignment():
    ev = faults.LinkFlap(links=(0,), start_epoch=1, end_epoch=2,
                         duty=0.5, scale=0.0)
    camp = faults.FaultCampaign(events=(ev,), n_segments=8)
    # PR 6 pins (align default): unchanged
    assert camp.seg_steps(100) == 13 and camp.seg_steps(3) == 1
    assert camp.seg_steps(100, align=20) == 20
    assert camp.seg_steps(1000, align=20) == 140  # ceil(125 -> 140)
    assert camp.seg_steps(1000, align=1) == 125


# ------------------------------------------- DCQCN closed-form forward
@pytest.mark.parametrize("n_steps", [1, 5, 6, 17, 64])
def test_dcqcn_fast_forward_matches_iterated_steps(n_steps):
    """With no marks and rc == rt == line rate, ``dcqcn.fast_forward``
    must reproduce n iterated ``dcqcn.step`` calls: alpha decay, CNP/rate
    timers (including periodic rate-timer firings), recovery stage."""
    p = dcqcn.DCQCNParams()
    line = 100e9
    dt = 10e-6
    st0 = dcqcn.init_state((3,), line)
    st0 = st0._replace(t_since_rate=jnp.array([0.0, 30e-6, 54e-6]),
                       recovery_stage=jnp.array([0.0, 2.0, 7.0]))
    active = jnp.array([True, True, False])
    it = st0
    for _ in range(n_steps):
        new, _ = dcqcn.step(it, jnp.zeros(3), active, dt, line, p)
        # inactive sub-flows hold state like the compact engine's masked
        # update (dcqcn_phase applies the step only where active)
        it = type(st0)(*(jnp.where(active, a, b) for a, b in zip(new, it)))
    ff = dcqcn.fast_forward(st0, active, n_steps, dt, p)
    for name in st0._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(ff, name)), np.asarray(getattr(it, name)),
            rtol=2e-4, atol=1e-9, err_msg=f"{name} @ n={n_steps}")


def test_queue_fast_forward_matches_integrated():
    """Closed-form clip trajectory == n iterated integrate_queue steps
    under a frozen arrival/capacity vector."""
    from repro.netsim import dataplane

    rng = np.random.default_rng(0)
    L = 16
    q0 = jnp.asarray(rng.uniform(0, 2e6, L + 1).astype(np.float32))
    arr = jnp.asarray(rng.uniform(0, 2e11, L + 1).astype(np.float32))
    cap = jnp.full((L + 1,), 1e11, jnp.float32)
    qmask = jnp.ones((L + 1,), jnp.float32)
    q_ff, mq = dataplane.queue_fast_forward(
        q0, arr, cap, qmask, dt=10e-6, n_steps=9, qmax_bytes=8e6, n_links=L)
    q = q0
    mq_it = []
    for _ in range(9):
        q = jnp.clip(q + (arr - cap) * (10e-6 / 8.0), 0.0, 8e6) * qmask
        mq_it.append(float(jnp.max(q[:L])))
    np.testing.assert_allclose(np.asarray(q_ff), np.asarray(q), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mq), np.asarray(mq_it), rtol=1e-6)


# --------------------------------------------- executable-cache builds
def test_cache_build_counts_pinned():
    """One executable per (cfg, shape): the second dispatch of the same
    sim must add zero builds, and toggling ``adaptive`` compiles its own
    program without evicting the first."""
    topo = topology.leaf_spine(2, 4, 4, 100e9)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="websearch", load=0.3, duration_s=0.6e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=3,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=2 * 4 * 100e9))
    cfg = engine.SimConfig(scheme="ecmp", duration_s=2e-3,
                           uplink_sample_every=10)
    sweep.clear_cache()
    sweep.run_one(topo, cfg, trace)
    b1 = sweep.cache_stats()["builds"]
    assert b1 == 1
    sweep.run_one(topo, cfg, trace)
    assert sweep.cache_stats()["builds"] == b1
    assert sweep.cache_stats()["hits"] >= 1
    sweep.run_one(topo, dataclasses.replace(cfg, adaptive=True), trace)
    b2 = sweep.cache_stats()["builds"]
    assert b2 == b1 + 1
    sweep.run_one(topo, dataclasses.replace(cfg, adaptive=True), trace)
    sweep.run_one(topo, cfg, trace)
    assert sweep.cache_stats()["builds"] == b2


# ----------------------------------------------- quiescence profiling
def test_quiescence_profile_smoke():
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=14e-3,
                           uplink_sample_every=10)
    q = profile.quiescence_profile(topo, cfg, _collective(topo), iters=3)
    assert 0.0 < q["ff_fraction"] <= 1.0
    assert q["predicate_us"] > 0.0
    covered = sum(k * v for k, v in q["macro_hist"].items())
    assert covered == round(q["ff_fraction"] * q["n_chunks"]) * q["chunk_steps"]
    assert q["chunk_steps"] % cfg.uplink_sample_every == 0
