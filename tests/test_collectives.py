"""SeqBalance collective engine: correctness on 8 fake devices (subprocess
so the main test process keeps its single real CPU device), plus the pure
planning logic."""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import PathPlan, quantize_int8, dequantize_int8
from repro.dist import elastic

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multi_device(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_seqbalance_all_reduce_equals_psum():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import PathPlan, seqbalance_all_reduce

        mesh = jax.make_mesh((8,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(8 * 37, dtype=jnp.float32).reshape(8, 37)

        def f(x):
            plan = PathPlan(n_chunks=4, directions=(1, -1))
            return seqbalance_all_reduce(x, "pod", plan)

        g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        got = np.asarray(g(x))
        want = np.broadcast_to(x.sum(0, keepdims=True), x.shape)
        err = float(np.abs(got - want).max())
        # also with an inactive path (congestion-table reroute)
        def f2(x):
            plan = PathPlan(n_chunks=4, directions=(1, -1), inactive=(True, False))
            return seqbalance_all_reduce(x, "pod", plan)
        g2 = jax.jit(jax.shard_map(f2, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        err2 = float(np.abs(np.asarray(g2(x)) - want).max())
        # bf16 wire
        def f3(x):
            plan = PathPlan(n_chunks=2, wire_dtype="bfloat16")
            return seqbalance_all_reduce(x, "pod", plan)
        g3 = jax.jit(jax.shard_map(f3, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        err3 = float(np.abs(np.asarray(g3(x)) - want).max() / np.abs(want).max())
        print(json.dumps({"err": err, "err_inactive": err2, "err_bf16": err3}))
    """)
    r = run_multi_device(code)
    assert r["err"] < 1e-4
    assert r["err_inactive"] < 1e-4
    assert r["err_bf16"] < 2e-2  # bf16 wire: bounded quantization error


def test_chunk_paths_avoid_inactive():
    plan = PathPlan(n_chunks=4, directions=(1, -1), inactive=(True, False))
    assert plan.chunk_paths() == (1, 1, 1, 1)
    plan = PathPlan(n_chunks=4, directions=(1, -1), inactive=(False, False))
    assert plan.chunk_paths() == (0, 1, 0, 1)
    plan = PathPlan(n_chunks=3, directions=(1, -1, 1, -1),
                    inactive=(False, True, False, True))
    assert plan.chunk_paths() == (0, 2, 0)


def test_all_paths_inactive_falls_back():
    plan = PathPlan(n_chunks=2, directions=(1, -1), inactive=(True, True))
    assert plan.chunk_paths() == (0, 0)  # paper: traffic must still flow


def test_int8_quantization_error_feedback():
    x = jnp.asarray(np.random.default_rng(0).normal(size=1000).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.asarray(x - dequantize_int8(q, s))
    assert np.abs(err).max() <= float(s) * 0.5 + 1e-7  # round-to-nearest bound


def test_link_health_phi_semantics():
    lh = elastic.LinkHealth(n_paths=4, phi_steps=3)
    lh.report_slow(1, step=10)
    assert lh.inactive(12) == (False, True, False, False)
    lh.report_slow(1, step=12)  # refresh extends
    assert lh.inactive(14) == (False, True, False, False)
    assert lh.inactive(15) == (False, False, False, False)
    plan = lh.plan(step=12, n_chunks=4)
    assert 1 not in plan.chunk_paths()


def test_remesh_plan():
    p = elastic.remesh_plan((4, 16, 16), failed_pods=(2,), resume_step=1234)
    assert p.new_shape == (3, 16, 16)
    assert p.surviving_pods == (0, 1, 3)
    assert abs(p.per_pod_batch_scale - 4 / 3) < 1e-9
    import pytest
    with pytest.raises(RuntimeError):
        elastic.remesh_plan((2, 16, 16), failed_pods=(0, 1), resume_step=0)


def test_straggler_policy_quarantines_after_k_misses():
    sp = elastic.StragglerPolicy(deadline_s=1.0, max_misses=2)
    assert sp.observe(3, 0.5) == "ok"
    assert sp.observe(3, 2.0) == "warn"
    assert sp.observe(3, 2.0) == "quarantine"
    assert sp.observe(3, 0.5) == "ok"  # recovery resets
