"""Unit + property tests for the SeqBalance core (paper mechanisms)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import baselines, congestion_table as ctab, gbn, hashing, routing, shaper


# ------------------------------------------------------------------ shaper
@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**28), st.integers(1, 32))
def test_split_wqe_conserves_and_balances(size, n):
    parts = np.asarray(shaper.split_wqe(jnp.asarray(size, jnp.int32), n))
    assert parts.sum() == size  # no byte lost or invented
    assert parts.max() - parts.min() <= 1  # "sub-flows of equal size"
    assert (parts >= 0).all()


@settings(max_examples=50, deadline=None)
@given(st.floats(1.0, 1e9), st.integers(1, 16))
def test_split_wqe_float_fluid(size, n):
    parts = np.asarray(shaper.split_wqe(jnp.asarray(size, jnp.float32), n))
    np.testing.assert_allclose(parts.sum(), size, rtol=1e-5)
    assert np.ptp(parts) < 1e-3 * size + 1e-6


def test_subflow_five_tuples_distinct():
    """Each sub-WQE rides its own QP -> distinct sports -> distinct hashes
    (the entropy multiplication that makes ECMP-style hashing work for AI
    traffic, paper §III.C)."""
    src, dst, sport, dport = shaper.subflow_five_tuples(
        jnp.uint32(5), jnp.uint32(9), jnp.uint32(1234), 8
    )
    assert len(set(np.asarray(sport).tolist())) == 8
    h = hashing.hash_five_tuple(src, dst, sport, dport)
    assert len(set(np.asarray(h).tolist())) == 8


# ------------------------------------------------------------------- CQE
def test_cqe_bitmap_complete_only_when_all_acked():
    st_ = shaper.CQEState.create(3, jnp.array([4, 2, 1]))
    st_ = shaper.ack_mask(st_, jnp.array([[1, 1, 1, 0], [1, 1, 0, 0], [1, 0, 0, 0]], bool))
    ready = np.asarray(shaper.cqe_ready(st_))
    assert ready.tolist() == [False, True, True]
    st_ = shaper.ack_subwqe(st_, jnp.array([0]), jnp.array([3]))
    assert bool(shaper.cqe_ready(st_)[0])


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 32), st.sets(st.integers(0, 31), max_size=32))
def test_cqe_bitmap_property(n_sub, acks):
    st_ = shaper.CQEState.create(1, n_sub)
    mask = np.zeros((1, 32), bool)
    for a in acks:
        mask[0, a] = True
    st_ = shaper.ack_mask(st_, jnp.asarray(mask[:, :32]))
    expect = set(range(n_sub)).issubset(acks)
    assert bool(shaper.cqe_ready(st_)[0]) == expect
    assert int(shaper.popcount32(st_.bitmap)[0]) == len(acks)


def test_ack_idempotent():
    st_ = shaper.CQEState.create(1, 4)
    for _ in range(3):
        st_ = shaper.ack_subwqe(st_, jnp.array([0]), jnp.array([1]))
    assert int(shaper.popcount32(st_.bitmap)[0]) == 1


# -------------------------------------------------------- congestion table
def test_congestion_table_phi_expiry_and_refresh():
    t = ctab.CongestionTable.create(2, 8)
    t = ctab.mark_congested(t, jnp.array([0]), jnp.array([3]), now=10.0, phi=2.0)
    assert bool(ctab.is_inactive(t, jnp.array([0]), jnp.array([3]), 11.9))
    assert not bool(ctab.is_inactive(t, jnp.array([0]), jnp.array([3]), 12.1))
    # refresh restarts the timer (paper: "restarting the timing from phi")
    t = ctab.mark_congested(t, jnp.array([0]), jnp.array([3]), now=11.0, phi=2.0)
    assert bool(ctab.is_inactive(t, jnp.array([0]), jnp.array([3]), 12.5))
    assert not bool(ctab.is_inactive(t, jnp.array([0]), jnp.array([3]), 13.1))


@settings(max_examples=100, deadline=None)
@given(st.floats(0.0, 100.0), st.floats(0.1, 50.0), st.floats(0.0, 200.0))
def test_congestion_table_monotone(now, phi, query):
    t = ctab.CongestionTable.create(1, 4)
    t = ctab.mark_congested(t, jnp.array([0]), jnp.array([1]), now=now, phi=phi)
    inactive = bool(ctab.is_inactive(t, jnp.array([0]), jnp.array([1]), query))
    # expiry arithmetic happens in f32 inside the table
    expiry = float(np.float32(np.float32(now) + np.float32(phi)))
    assert inactive == (np.float32(query) < expiry)


def test_congestion_table_occupancy_small():
    """Paper §V: switch memory for the table is bounded by path count."""
    t = ctab.CongestionTable.create(4, 16)
    t = ctab.mark_congested(t, jnp.array([0, 0, 1]), jnp.array([1, 2, 5]), 0.0, 1.0)
    assert int(ctab.occupancy(t, 0.5).sum()) == 3


# ---------------------------------------------------------------- routing
def test_select_paths_avoids_inactive():
    inact = jnp.zeros((64, 8), bool).at[:, [2, 5]].set(True)
    src = jnp.arange(64, dtype=jnp.uint32)
    p = routing.select_paths(src, 1, 2, 3, inact, 8)
    assert not np.isin(np.asarray(p), [2, 5]).any()


def test_select_paths_all_inactive_falls_back_to_hash():
    inact = jnp.ones((16, 8), bool)
    src = jnp.arange(16, dtype=jnp.uint32)
    p = routing.select_paths(src, 1, 2, 3, inact, 8)
    e = routing.ecmp_paths(src, jnp.uint32(1), jnp.uint32(2), jnp.uint32(3), 8)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(e))


def test_routing_deterministic_no_reorder():
    """Same five-tuple + same table state => same path: packets of one
    sub-flow can never diverge (the no-reordering invariant)."""
    inact = jnp.zeros((8, 8), bool).at[:, 0].set(True)
    src = jnp.arange(8, dtype=jnp.uint32)
    p1 = routing.select_paths(src, 7, 9, 4791, inact, 8)
    p2 = routing.select_paths(src, 7, 9, 4791, inact, 8)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_ecmp_uniformity():
    n = 20000
    src = jnp.arange(n, dtype=jnp.uint32)
    p = np.asarray(routing.ecmp_paths(src, jnp.uint32(1), jnp.uint32(2), jnp.uint32(3), 12))
    counts = np.bincount(p, minlength=12)
    assert counts.min() > n / 12 * 0.9 and counts.max() < n / 12 * 1.1


# ------------------------------------------------------------------ hashes
@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_fmix32_bijective_nontrivial(x):
    h = int(hashing.fmix32(jnp.uint32(x)))
    assert 0 <= h < 2**32
    if x != 0:
        assert h != x or x in (0,)  # avalanche makes fixed points unlikely


def test_double_hash_covers_all_paths_pow2():
    h1 = jnp.uint32(12345)
    h2 = jnp.uint32(999)
    seq = np.asarray(hashing.double_hash_sequence(h1, h2, 8, 8))
    assert sorted(seq.tolist()) == list(range(8))  # odd stride => full cycle


# -------------------------------------------------------------------- GBN
def test_table1_inflation_matches_paper():
    """Table I: one delayed packet -> >=3x FCT; small flows hurt more."""
    r64 = float(gbn.table1_inflation(jnp.float32(64e3)))
    r1m = float(gbn.table1_inflation(jnp.float32(1e6)))
    assert r64 == pytest.approx(5.77, rel=0.05)
    assert r1m == pytest.approx(3.01, rel=0.15)
    assert r64 > r1m > 2.8  # "minimum threefold increase" (approx)


def test_gbn_goodput_monotone():
    p = jnp.linspace(0, 1, 11)
    g = np.asarray(gbn.gbn_goodput_factor(p, 16))
    assert (np.diff(g) < 0).all() and g[0] == 1.0


def test_flowlet_gap_rdma_vs_tcp():
    """Fig. 1's mechanism: at RDMA line rates the inter-packet gap never
    exceeds the flowlet timeout, so flowlets cannot be detected."""
    gap_rdma = bool(baselines.flowlet_gap_occurs(jnp.float32(25e9), 1000.0, 100e-6))
    gap_slow = bool(baselines.flowlet_gap_occurs(jnp.float32(50e6), 1000.0, 100e-6))
    assert not gap_rdma and gap_slow


def test_drill_weights_prefer_short_queues():
    q = jnp.array([[0.0, 1e6, 1e6, 1e6]])
    w = np.asarray(baselines.drill_weights(q))
    assert w.argmax() == 0 and w.sum() == pytest.approx(1.0, abs=1e-5)
