"""Multi-epoch co-simulation driver (dist.cosim) + the traced-capacity
sweep contract it rides on:

  * capacity as a traced sweep operand is bit-identical to the baked-in
    constant and reuses ONE compiled program across capacity changes;
  * collective_trace's ECMP steering pins QPs onto their planned fabric
    paths exactly as the engine's own five-tuple hash will route them
    (drift between the two would silently unbind every plan);
  * the killed-spine round trip on a forced 8-device host platform:
    failure -> quarantine/reroute within an epoch -> recovery -> phi
    release -> plan churn settles to zero.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------- traced capacity
def test_traced_capacity_matches_static_and_reuses_program():
    from repro.netsim import sweep, topology, workloads
    from repro.netsim.engine import SimConfig

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    tr = workloads.poisson_trace(workloads.TraceConfig(
        workload="fixed:1e6", load=0.5, duration_s=1e-3, n_hosts=topo.n_hosts,
        host_bw=40e9, seed=3, hosts_per_leaf=2))
    cfg = SimConfig(scheme="seqbalance", duration_s=1e-3)
    r_static, o_static = sweep.run_one(topo, cfg, tr)
    cap = np.asarray(topo.capacity).copy()
    r_traced, o_traced = sweep.run_one(topo, cfg, tr, capacity=cap)
    np.testing.assert_array_equal(r_static.finish, r_traced.finish)
    np.testing.assert_array_equal(np.asarray(o_static.uplink_load),
                                  np.asarray(o_traced.uplink_load))

    # capacity changes reuse the SAME executable (the whole point): two
    # more runs with different fault states add zero builds ...
    before = sweep.cache_stats()["builds"]
    cap_dead = cap.copy()
    cap_dead[[1, 2 * 4 + 1]] = 0.0  # kill spine 1 both directions (leaf 0)
    r_dead, _ = sweep.run_one(topo, cfg, tr, capacity=cap_dead)
    cap_brown = cap.copy()
    cap_brown[:8] *= 0.5
    sweep.run_one(topo, cfg, tr, capacity=cap_brown)
    assert sweep.cache_stats()["builds"] == before
    # ... and the physics actually responded to the degraded fabric
    assert not np.array_equal(r_traced.finish, r_dead.finish)


def test_run_jobs_callable_and_kwargs_spellings():
    from repro.netsim import sweep, topology, workloads
    from repro.netsim.engine import SimConfig

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    tr = workloads.poisson_trace(workloads.TraceConfig(
        workload="fixed:1e6", load=0.4, duration_s=5e-4, n_hosts=topo.n_hosts,
        host_bw=40e9, seed=5, hosts_per_leaf=2))
    cfg = SimConfig(scheme="ecmp", duration_s=5e-4)
    cap = np.asarray(topo.capacity).copy()
    ref = sweep.run_batch(topo, cfg, [tr], capacity=cap)
    out = sweep.run_jobs([
        (topo, cfg, [tr]),                          # classic triple
        (topo, cfg, [tr], dict(capacity=cap)),      # kwargs spelling
        lambda: sweep.run_batch(topo, cfg, [tr], capacity=cap),  # callable
    ])
    assert len(out) == 3
    np.testing.assert_array_equal(out[1][0][0].finish, ref[0][0].finish)
    np.testing.assert_array_equal(out[2][0][0].finish, ref[0][0].finish)


# ------------------------------------------------------------ steering
def test_collective_trace_steering_matches_engine_hash():
    """The steered flow ids must land on their planned fabric paths under
    the ENGINE's own five-tuple construction (flow_constants -> ecmp_paths)
    — this pins workloads._ecmp_steered_fids against engine.flow_constants
    so the two cannot drift apart silently."""
    import jax.numpy as jnp

    from repro.core import routing
    from repro.dist.elastic import LinkHealth
    from repro.netsim import engine, topology, workloads

    topo = topology.three_tier(n_tor=4, n_agg=4, n_core=2, hosts_per_tor=2,
                               bw_tor_agg=40e9, bw_agg_core=10e9,
                               host_bw=10e9)
    P = topo.n_paths
    health = LinkHealth(n_paths=P, phi_steps=2)
    health.report_slow(3, step=0)  # quarantine path 3
    plan = health.plan(1, n_chunks=4)
    hosts = [(i % 4) * 2 + (i // 4) for i in range(6)]
    tr = workloads.collective_trace(plan, hosts, 2e6, link_bw=40e9,
                                    steer_paths=P)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=1e-3)
    fc = engine.flow_constants(topo, cfg, jnp.asarray(tr.sizes),
                               jnp.asarray(tr.src), jnp.asarray(tr.dst),
                               jnp.asarray(tr.flow_id))
    realized = np.asarray(routing.ecmp_paths(*fc.f5, P))
    # the plan's active spread: member i's chunk-c QP targets
    # active[(i * n_chunks + c) % n_active], repeated every round
    active = [p for p in range(P) if not plan.inactive[p]]
    n, n_chunks = len(hosts), plan.n_chunks
    per_round = [active[(i * n_chunks + c) % len(active)]
                 for c in range(n_chunks) for i in range(n)]
    expect = np.asarray(per_round * (2 * (n - 1)), np.int32)
    np.testing.assert_array_equal(realized, expect)
    assert 3 not in realized  # the quarantined path carries nothing


# ----------------------------------------------- driver round trip (8 dev)
def test_cosim_driver_killed_spine_round_trip_8dev():
    """Fig. 11 as a regression: spine killed at epoch 2 (recovering at 5)
    on a forced 8-device host platform.  The driver must (1) degrade then
    re-converge within one epoch of the kill, (2) quarantine the dead
    spine's path while it is down, (3) release it exactly phi epochs after
    the last report, and (4) settle to zero plan churn — all epochs after
    the first reusing one compiled sweep program."""
    code = textwrap.dedent("""
        import json
        import numpy as np
        from repro.dist import cosim
        from repro.netsim import topology

        topo = topology.leaf_spine(4, 4, 2, 40e9)
        dead, kill, recover = 2, 2, 5
        hist = cosim.run_cosim(
            topo, cosim.ring_hosts(topo, 4), 4e6, scheme="ecmp", epochs=10,
            faults=(cosim.kill_spine(topo, dead, epoch=kill,
                                     recover_epoch=recover),),
            phi_steps=2, n_chunks=4)
        rs = hist.records
        out = dict(
            conv=hist.convergence_epoch(kill),
            baseline_p99=hist.baseline_p99(kill),
            p99=[r.fct_p99_s for r in rs],
            completion=[r.completion for r in rs],
            quarantined=[list(r.quarantined) for r in rs],
            churn=[r.plan_churn for r in rs],
            builds=[r.new_builds for r in rs],
            expiry=hist.health.expiry(dead),
            final_inactive=list(hist.final_plan.inactive),
        )
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    dead, kill, recover = 2, 2, 5
    # (1) the kill epoch hurts; re-routed within one epoch of the kill
    assert out["completion"][kill] < 1.0 or \
        out["p99"][kill] > 2.0 * out["baseline_p99"]
    assert out["conv"] is not None and out["conv"] - kill <= 2
    # (2) quarantined from the epoch after the kill until recovery
    for e in range(kill + 1, recover + 1):
        assert dead in out["quarantined"][e], (e, out["quarantined"])
    # (3) the last report refreshes while the spine is down (capacity rule)
    # -> release exactly phi epochs after the last down epoch
    assert out["expiry"] == (recover - 1) + 2
    released = out["expiry"]
    for e in range(released, len(out["quarantined"])):
        assert dead not in out["quarantined"][e]
    # (4) after release: churn settles to zero and the final plan is clean
    assert all(c == 0 for c in out["churn"][released:])
    assert not any(out["final_inactive"])
    # p99 recovered and stays recovered after the reroute epoch
    for e in range(kill + 1, len(out["p99"])):
        assert out["p99"][e] <= 1.10 * out["baseline_p99"], (e, out["p99"])
        assert out["completion"][e] == 1.0
    # traced capacity: one program, zero rebuilds after epoch 0
    assert out["builds"][0] >= 1 and sum(out["builds"][1:]) == 0


def test_fct_samples_censors_unfinished_flows():
    from repro.netsim import metrics
    from repro.netsim.workloads import Trace

    class _S:
        finish = np.array([2e-4, np.inf, 5e-4, np.inf], np.float32)

    tr = Trace(sizes=np.ones(4, np.float32),
               arrivals=np.array([0.0, 1e-4, 2e-4, 9e-4], np.float32),
               src=np.zeros(4, np.int32), dst=np.zeros(4, np.int32),
               flow_id=np.arange(4, dtype=np.uint32),
               valid=np.array([True, True, True, False]))
    fct, completion = metrics.fct_samples(_S(), tr, horizon_s=1e-3)
    np.testing.assert_allclose(fct, [2e-4, 9e-4, 3e-4], rtol=1e-6)
    assert completion == pytest.approx(2 / 3)
