"""repro.dist extras: chunk->path planning properties, seqbalance == psum
across mesh sizes, and the netsim co-simulation round trip (a killed spine
is detected from the fluid sim and routed around by the next PathPlan)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dist import netfeed
from repro.dist.collectives import PathPlan
from repro.dist.elastic import LinkHealth, alternating_directions
from repro.netsim import topology, workloads

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------- planning properties
def test_chunk_paths_property_never_inactive_unless_all_dead():
    rng = np.random.default_rng(0)
    for _ in range(500):
        n_paths = int(rng.integers(1, 9))
        n_chunks = int(rng.integers(1, 17))
        inactive = tuple(bool(b) for b in rng.integers(0, 2, n_paths))
        plan = PathPlan(n_chunks=n_chunks,
                        directions=alternating_directions(n_paths),
                        inactive=inactive)
        paths = plan.chunk_paths()
        assert len(paths) == n_chunks
        assert all(0 <= p < n_paths for p in paths)
        if all(inactive):
            # total quarantine carries no routing signal: traffic must
            # still flow, on the primary path
            assert paths == (0,) * n_chunks
        else:
            assert not any(inactive[p] for p in paths)
            # round-robin: active paths are used near-uniformly
            active = [p for p in range(n_paths) if not inactive[p]]
            counts = [paths.count(p) for p in active]
            assert max(counts) - min(counts) <= 1
        assert plan.chunk_paths() == paths  # deterministic


# ------------------------------------------------- collective == psum (2/4/8)
def test_seqbalance_matches_psum_across_mesh_sizes():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import PathPlan, seqbalance_all_reduce

        out = {}
        for n in (2, 4, 8):
            mesh = jax.make_mesh((n,), ("pod",), devices=jax.devices()[:n])
            x = jax.random.normal(jax.random.PRNGKey(n), (n, 65),
                                  dtype=jnp.float32)
            plan = PathPlan(n_chunks=3, directions=(1, -1))

            def seq(x):
                return seqbalance_all_reduce(x, "pod", plan)

            def ref(x):
                return jax.lax.psum(x, "pod")

            gs = jax.jit(jax.shard_map(seq, mesh=mesh, in_specs=P("pod"),
                                       out_specs=P("pod")))
            gr = jax.jit(jax.shard_map(ref, mesh=mesh, in_specs=P("pod"),
                                       out_specs=P("pod")))
            out[str(n)] = float(np.abs(np.asarray(gs(x)) -
                                       np.asarray(gr(x))).max())
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    errs = json.loads(r.stdout.strip().splitlines()[-1])
    for n, err in errs.items():
        assert err < 1e-4, (n, errs)


# --------------------------------------------------- netsim feedback adapter
class _FakeOuts:
    def __init__(self, uplink_load):
        self.uplink_load = uplink_load


def test_report_congestion_overload_rule():
    topo = topology.leaf_spine(2, 4, 2, 40e9)
    # leaf 0 offers 2x capacity on uplink 1, idle elsewhere
    up = np.zeros((10, 2, 4), np.float32)
    up[:, 0, 1] = 80e9
    lh = LinkHealth(n_paths=topo.n_paths, phi_steps=4)
    slow = netfeed.report_congestion(lh, topo, _FakeOuts(up), step=5,
                                     overload=1.5)
    assert slow == (1,)
    assert lh.inactive(6) == (False, True, False, False)
    assert lh.inactive(9) == (False, False, False, False)  # phi expired


def test_collective_trace_shape_and_schedule():
    plan = PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    tr = workloads.collective_trace(plan, [0, 2, 4, 6], 2e6, link_bw=40e9)
    n, rounds = 4, 2 * (4 - 1)
    assert tr.sizes.size == rounds * plan.n_chunks * n
    assert tr.valid.all()
    np.testing.assert_allclose(tr.sizes, 2e6 / (n * plan.n_chunks))
    # ring invariant: every flow connects distinct adjacent ring members
    ring = {0: 0, 2: 1, 4: 2, 6: 3}
    for s, d in zip(tr.src, tr.dst):
        assert (ring[int(d)] - ring[int(s)]) % n in (1, n - 1)
    # an inactive path shifts its chunks onto surviving directions
    tr2 = workloads.collective_trace(
        PathPlan(n_chunks=4, directions=(1, -1, 1, -1),
                 inactive=(True, False, True, False)),
        [0, 2, 4, 6], 2e6, link_bw=40e9)
    assert (np.sort(tr2.arrivals) == np.sort(tr.arrivals)).all()


# ------------------------------------------------------------- phi expiry
def test_phi_expiry_releases_exactly_phi_steps_after_last_report():
    """A quarantined path re-enters LinkHealth.plan at EXACTLY
    last_report + phi_steps — one step earlier it is still out, and a
    refreshing report pushes the release out by the same amount."""
    lh = LinkHealth(n_paths=6, phi_steps=5)
    lh.report_slow(2, step=10)
    assert lh.expiry(2) == 15
    assert lh.plan(14).inactive[2] and 2 not in lh.plan(14).chunk_paths()
    assert not lh.plan(15).inactive[2]  # released exactly at +phi
    assert 2 in lh.plan(15, n_chunks=12).chunk_paths()
    # refresh: a new report EXTENDS the window from the newest report
    lh.report_slow(2, step=13)
    assert lh.expiry(2) == 18
    assert lh.plan(17).inactive[2] and not lh.plan(18).inactive[2]
    # a stale (out-of-order) report must not shrink the window
    lh.report_slow(2, step=11)
    assert lh.expiry(2) == 18


def test_phi_expiry_seeded_regression():
    """Randomized report patterns: inactive(step) is always equivalent to
    "strictly fewer than phi_steps steps since the newest report"."""
    rng = np.random.default_rng(7)
    for _ in range(200):
        n_paths = int(rng.integers(1, 8))
        phi = int(rng.integers(1, 9))
        lh = LinkHealth(n_paths=n_paths, phi_steps=phi)
        newest = {}
        for _ in range(int(rng.integers(1, 12))):
            p = int(rng.integers(0, n_paths))
            s = int(rng.integers(0, 30))
            lh.report_slow(p, s)
            newest[p] = max(newest.get(p, -1), s)
        probe = int(rng.integers(0, 45))
        expect = tuple(
            p in newest and probe < newest[p] + phi for p in range(n_paths)
        )
        assert lh.inactive(probe) == expect
        for p, s in newest.items():
            assert lh.expiry(p) == s + phi


# ------------------------------------------- three_tier uplink -> path fanout
def _three_tier_small():
    return topology.three_tier(n_tor=3, n_agg=4, n_core=2, hosts_per_tor=2,
                               bw_tor_agg=40e9, bw_agg_core=10e9,
                               host_bw=10e9)


def _check_uplink_quarantine(topo, overloaded: set[tuple[int, int]]):
    """Overload the given (leaf, uplink) pairs and assert report_congestion
    quarantines exactly the n_core paths of each overloaded uplink."""
    T, A = topo.uplink_ids.shape
    C = topo.n_paths // A
    cap = np.asarray(topo.capacity)[np.asarray(topo.uplink_ids)]  # [T, A]
    up = np.zeros((5, T, A), np.float32)
    for (l, a) in overloaded:
        up[:, l, a] = 3.0 * cap[l, a]
    lh = LinkHealth(n_paths=topo.n_paths, phi_steps=4)
    slow = netfeed.report_congestion(lh, topo, _FakeOuts(up), step=0,
                                     overload=1.5)
    expect = {a * C + c for (_, a) in overloaded for c in range(C)}
    assert set(slow) == expect
    assert lh.inactive(1) == tuple(p in expect for p in range(topo.n_paths))


def test_three_tier_uplink_quarantines_exactly_its_core_paths_seeded():
    """Always-on seeded twin of the hypothesis property: an overloaded ToR
    uplink a quarantines exactly the n_core paths (a, *) and nothing
    else."""
    topo = _three_tier_small()
    rng = np.random.default_rng(11)
    for _ in range(25):
        T, A = topo.uplink_ids.shape
        k = int(rng.integers(1, 4))
        overloaded = {(int(rng.integers(0, T)), int(rng.integers(0, A)))
                      for _ in range(k)}
        _check_uplink_quarantine(topo, overloaded)


def test_three_tier_uplink_quarantine_property():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    topo = _three_tier_small()
    T, A = topo.uplink_ids.shape

    @settings(max_examples=40, deadline=None)
    @given(st.sets(st.tuples(st.integers(0, T - 1), st.integers(0, A - 1)),
                   min_size=1, max_size=5))
    def run(overloaded):
        _check_uplink_quarantine(topo, overloaded)

    run()


# --------------------------------------------------- dead-capacity reporting
def test_path_utilization_reports_dead_capacity_not_idle():
    """A downed spine (capacity 0) must read +inf utilization, not 0: the
    offered load on it decays once DCQCN chokes the victims, and the old
    max(cap, 1) floor made the one unusable path look like the idlest."""
    topo = topology.leaf_spine(2, 4, 2, 40e9)
    up = np.zeros((10, 2, 4), np.float32)  # no offered load anywhere
    cap = np.asarray(topo.capacity).copy()
    dead = 2
    cap[0 * 4 + dead] = 0.0  # up[leaf0, spine2]
    util = netfeed.path_utilization(topo, _FakeOuts(up), capacity=cap)
    assert np.isinf(util[dead])
    assert (util[[0, 1, 3]] == 0.0).all()
    # and the overload rule alone now catches it (no dead-frac needed)
    lh = LinkHealth(n_paths=4, phi_steps=4)
    slow = netfeed.report_congestion(lh, topo, _FakeOuts(up), step=0,
                                     capacity=cap, dead_capacity_frac=0.0)
    assert dead in slow


def test_cosim_round_trip_reroutes_around_killed_spine():
    """collective_trace under a killed-spine topology -> the fluid sim's
    per-path stats mark the path slow -> the next PathPlan avoids it."""
    L, S = 4, 4
    dead = 2
    overrides = {}
    for leaf in range(L):
        overrides[leaf * S + dead] = 1e6  # up[l, dead] effectively down
        overrides[L * S + dead * L + leaf] = 1e6  # down[dead, l]
    topo = topology.leaf_spine(L, S, 2, 40e9, capacity_overrides=overrides)
    plan = PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    hosts = [0, 2, 4, 6]  # one ring member per leaf

    res = netfeed.co_simulate(topo, plan, hosts, 2e6, scheme="ecmp",
                              duration_s=2e-3, step=100)
    assert dead in res.slow_paths
    # ECMP kept hashing traffic onto the dead spine: the offered-load /
    # capacity ratio itself screams (the congestion rule, not just the
    # capacity floor)
    util = netfeed.path_utilization(topo, res.outs)
    assert util[dead] > 10.0, util
    # the replanned collective routes around it
    assert res.health.inactive(100)[dead]
    assert dead not in res.plan.chunk_paths()
    assert set(res.plan.chunk_paths()) <= {p for p in range(S) if p != dead}
