"""repro.dist extras: chunk->path planning properties, seqbalance == psum
across mesh sizes, and the netsim co-simulation round trip (a killed spine
is detected from the fluid sim and routed around by the next PathPlan)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dist import netfeed
from repro.dist.collectives import PathPlan
from repro.dist.elastic import LinkHealth, alternating_directions
from repro.netsim import topology, workloads

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------- planning properties
def test_chunk_paths_property_never_inactive_unless_all_dead():
    rng = np.random.default_rng(0)
    for _ in range(500):
        n_paths = int(rng.integers(1, 9))
        n_chunks = int(rng.integers(1, 17))
        inactive = tuple(bool(b) for b in rng.integers(0, 2, n_paths))
        plan = PathPlan(n_chunks=n_chunks,
                        directions=alternating_directions(n_paths),
                        inactive=inactive)
        paths = plan.chunk_paths()
        assert len(paths) == n_chunks
        assert all(0 <= p < n_paths for p in paths)
        if all(inactive):
            # total quarantine carries no routing signal: traffic must
            # still flow, on the primary path
            assert paths == (0,) * n_chunks
        else:
            assert not any(inactive[p] for p in paths)
            # round-robin: active paths are used near-uniformly
            active = [p for p in range(n_paths) if not inactive[p]]
            counts = [paths.count(p) for p in active]
            assert max(counts) - min(counts) <= 1
        assert plan.chunk_paths() == paths  # deterministic


# ------------------------------------------------- collective == psum (2/4/8)
def test_seqbalance_matches_psum_across_mesh_sizes():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import PathPlan, seqbalance_all_reduce

        out = {}
        for n in (2, 4, 8):
            mesh = jax.make_mesh((n,), ("pod",), devices=jax.devices()[:n])
            x = jax.random.normal(jax.random.PRNGKey(n), (n, 65),
                                  dtype=jnp.float32)
            plan = PathPlan(n_chunks=3, directions=(1, -1))

            def seq(x):
                return seqbalance_all_reduce(x, "pod", plan)

            def ref(x):
                return jax.lax.psum(x, "pod")

            gs = jax.jit(jax.shard_map(seq, mesh=mesh, in_specs=P("pod"),
                                       out_specs=P("pod")))
            gr = jax.jit(jax.shard_map(ref, mesh=mesh, in_specs=P("pod"),
                                       out_specs=P("pod")))
            out[str(n)] = float(np.abs(np.asarray(gs(x)) -
                                       np.asarray(gr(x))).max())
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    errs = json.loads(r.stdout.strip().splitlines()[-1])
    for n, err in errs.items():
        assert err < 1e-4, (n, errs)


# --------------------------------------------------- netsim feedback adapter
class _FakeOuts:
    def __init__(self, uplink_load):
        self.uplink_load = uplink_load


def test_report_congestion_overload_rule():
    topo = topology.leaf_spine(2, 4, 2, 40e9)
    # leaf 0 offers 2x capacity on uplink 1, idle elsewhere
    up = np.zeros((10, 2, 4), np.float32)
    up[:, 0, 1] = 80e9
    lh = LinkHealth(n_paths=topo.n_paths, phi_steps=4)
    slow = netfeed.report_congestion(lh, topo, _FakeOuts(up), step=5,
                                     overload=1.5)
    assert slow == (1,)
    assert lh.inactive(6) == (False, True, False, False)
    assert lh.inactive(9) == (False, False, False, False)  # phi expired


def test_collective_trace_shape_and_schedule():
    plan = PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    tr = workloads.collective_trace(plan, [0, 2, 4, 6], 2e6, link_bw=40e9)
    n, rounds = 4, 2 * (4 - 1)
    assert tr.sizes.size == rounds * plan.n_chunks * n
    assert tr.valid.all()
    np.testing.assert_allclose(tr.sizes, 2e6 / (n * plan.n_chunks))
    # ring invariant: every flow connects distinct adjacent ring members
    ring = {0: 0, 2: 1, 4: 2, 6: 3}
    for s, d in zip(tr.src, tr.dst):
        assert (ring[int(d)] - ring[int(s)]) % n in (1, n - 1)
    # an inactive path shifts its chunks onto surviving directions
    tr2 = workloads.collective_trace(
        PathPlan(n_chunks=4, directions=(1, -1, 1, -1),
                 inactive=(True, False, True, False)),
        [0, 2, 4, 6], 2e6, link_bw=40e9)
    assert (np.sort(tr2.arrivals) == np.sort(tr.arrivals)).all()


def test_cosim_round_trip_reroutes_around_killed_spine():
    """collective_trace under a killed-spine topology -> the fluid sim's
    per-path stats mark the path slow -> the next PathPlan avoids it."""
    L, S = 4, 4
    dead = 2
    overrides = {}
    for leaf in range(L):
        overrides[leaf * S + dead] = 1e6  # up[l, dead] effectively down
        overrides[L * S + dead * L + leaf] = 1e6  # down[dead, l]
    topo = topology.leaf_spine(L, S, 2, 40e9, capacity_overrides=overrides)
    plan = PathPlan(n_chunks=4, directions=(1, -1, 1, -1))
    hosts = [0, 2, 4, 6]  # one ring member per leaf

    res = netfeed.co_simulate(topo, plan, hosts, 2e6, scheme="ecmp",
                              duration_s=2e-3, step=100)
    assert dead in res.slow_paths
    # ECMP kept hashing traffic onto the dead spine: the offered-load /
    # capacity ratio itself screams (the congestion rule, not just the
    # capacity floor)
    util = netfeed.path_utilization(topo, res.outs)
    assert util[dead] > 10.0, util
    # the replanned collective routes around it
    assert res.health.inactive(100)[dead]
    assert dead not in res.plan.chunk_paths()
    assert set(res.plan.chunk_paths()) <= {p for p in range(S) if p != dead}
