"""Chaos campaign framework (netsim.faults + the chaos-aware co-sim):

  * the fault vocabulary validates its schedules at construction (a
    typo'd event must fail loudly, not run a vacuously healthy epoch);
  * wall-clock capacity schedules cut flaps/pauses into the fixed-K
    segment grid the compact engine indexes with a static stride;
  * lossy links drive go-back-N goodput amplification INSIDE the
    dataplane — FCTs inflate while the compiled program is reused;
  * in-epoch replanning never reorders an in-flight QP: pre-cut rounds
    keep their flow ids, surviving steered QPs keep theirs across the
    cut, only dead-target QPs re-steer, ring directions never flip;
  * the sweep pool survives crashing / hanging jobs (retry, salvage,
    timeout) and the campaign journal resumes an interrupted run.
"""
import json
import os

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------- fault vocabulary
def test_fault_event_validation():
    from repro.dist.cosim import FaultEvent

    FaultEvent(1, (3, 4), 0.0, 2)  # well-formed
    with pytest.raises(AssertionError):
        FaultEvent(1, (), 0.0, 2)  # no links: silently applies to nothing
    with pytest.raises(AssertionError):
        FaultEvent(-1, (3,), 0.0)
    with pytest.raises(AssertionError):
        FaultEvent(2, (3,), 0.0, 2)  # end <= start: never active
    with pytest.raises(AssertionError):
        FaultEvent(1, (3,), -0.5, 2)


def test_campaign_event_validation():
    from repro.netsim import faults

    with pytest.raises(AssertionError):
        faults.LinkFlap(links=(), start_epoch=1)
    with pytest.raises(AssertionError):
        faults.LinkFlap(links=(1,), start_epoch=1, duty=0.0)
    with pytest.raises(AssertionError):
        faults.LinkFlap(links=(1,), start_epoch=1, onset_frac=1.0)
    with pytest.raises(AssertionError):
        faults.Brownout(links=(1,), scale=1.0, start_epoch=0)  # not a fault
    with pytest.raises(AssertionError):
        faults.LossyLink(links=(1,), loss_rate=0.0, start_epoch=0)
    with pytest.raises(AssertionError):
        faults.PauseWindow(links=(1,), start_epoch=2, end_epoch=2)
    with pytest.raises(AssertionError):
        faults.Straggler(rank=0, slowdown=1.0, start_epoch=0)  # not slow
    with pytest.raises(AssertionError):
        faults.FaultCampaign(events=(object(),))  # no .active(epoch)


def test_capacity_schedule_flap_segments():
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    links = topology.spine_links(topo, 1)
    ev = faults.LinkFlap(links=links, start_epoch=1, end_epoch=3,
                         period_frac=0.5, duty=0.5, scale=0.0)
    camp = faults.FaultCampaign(events=(ev,), n_segments=8)
    base = np.asarray(topo.capacity, np.float32)

    cap0 = camp.capacity_schedule(topo, 0)  # inactive epoch: all-healthy
    assert cap0.shape == (8, topo.n_links + 1)
    np.testing.assert_array_equal(cap0, np.repeat(base[None], 8, axis=0))

    # cycle = 4 segments, down for the first 2 of each: k in {0,1,4,5}
    cap1 = camp.capacity_schedule(topo, 1)
    down = np.array([cap1[k, links[0]] == 0.0 for k in range(8)])
    np.testing.assert_array_equal(
        down, [True, True, False, False, True, True, False, False])
    untouched = [l for l in range(topo.n_links) if l not in set(links)]
    np.testing.assert_array_equal(cap1[:, untouched],
                                  np.repeat(base[None], 8, axis=0)[:, untouched])

    # onset_frac delays the first down segment ONLY in the start epoch
    ev2 = faults.LinkFlap(links=links, start_epoch=1, end_epoch=3,
                          duty=1.0, onset_frac=0.5, scale=0.0)
    camp2 = faults.FaultCampaign(events=(ev2,), n_segments=8)
    c1 = camp2.capacity_schedule(topo, 1)
    c2 = camp2.capacity_schedule(topo, 2)
    assert [c1[k, links[0]] == 0.0 for k in range(8)] == [False] * 4 + [True] * 4
    assert all(c2[k, links[0]] == 0.0 for k in range(8))

    # seg_steps covers the horizon with the LAST row absorbing the remainder
    assert camp.seg_steps(100) == 13 and camp.seg_steps(3) == 1


def test_capacity_schedule_pause_and_brownout():
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    links = topology.spine_links(topo, 0)
    camp = faults.FaultCampaign(events=(
        faults.PauseWindow(links=links, start_epoch=0, onset_frac=0.25,
                           width_frac=0.25),
        faults.Brownout(links=topology.spine_links(topo, 2), scale=0.5,
                        start_epoch=0),
    ), n_segments=8)
    cap = camp.capacity_schedule(topo, 0)
    paused = [bool(cap[k, links[0]] == 0.0) for k in range(8)]
    assert paused == [False, False, True, True, False, False, False, False]
    b = topology.spine_links(topo, 2)[0]
    assert np.allclose(cap[:, b], 0.5 * np.float32(topo.capacity[b]))


def test_loss_vector_merge_and_arity():
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    l01 = topology.spine_links(topo, 0) + topology.spine_links(topo, 1)
    camp = faults.FaultCampaign(events=(
        faults.LossyLink(links=topology.spine_links(topo, 0), loss_rate=0.01,
                         start_epoch=1, end_epoch=3),
        faults.LossyLink(links=l01, loss_rate=0.002, start_epoch=1),
    ))
    clean = camp.loss_at(topo, 0)  # arity never changes: zeros when clean
    assert clean.shape == (topo.n_links + 1,) and not clean.any()
    loss = camp.loss_at(topo, 1)
    # overlapping lossy events merge by MAX, not sum
    assert loss[topology.spine_links(topo, 0)[0]] == np.float32(0.01)
    assert loss[topology.spine_links(topo, 1)[0]] == np.float32(0.002)
    loss3 = camp.loss_at(topo, 3)  # first event expired
    assert loss3[topology.spine_links(topo, 0)[0]] == np.float32(0.002)


def test_paths_for_link_inverts_spine_links():
    from repro.netsim import topology
    from repro.netsim.topology import paths_for_link, spine_links

    for topo in (topology.leaf_spine(2, 4, 2, 40e9),
                 topology.three_tier(4, 2, 2, 2, 100e9)):
        n_spines = topo.uplink_ids.shape[1]
        n_core = topo.n_paths // n_spines
        for s in range(n_spines):
            want = set(range(s * n_core, (s + 1) * n_core))
            for link in spine_links(topo, s):
                got = set(paths_for_link(topo, link))
                assert got and got <= want, (s, link, got, want)
        # host tx/rx links select no fabric path
        assert paths_for_link(topo, topo.n_links - 1) == ()


def test_random_campaign_deterministic():
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    a = faults.random_campaign(topo, seed=7, epochs=6, n_faults=4, n_ranks=6)
    b = faults.random_campaign(topo, seed=7, epochs=6, n_faults=4, n_ranks=6)
    assert a == b and len(a.events) == 4
    c = faults.random_campaign(topo, seed=8, epochs=6, n_faults=4, n_ranks=6)
    assert a != c
    # straggler kind is only drawable when the ring size is known
    d = faults.random_campaign(topo, seed=7, epochs=6, n_faults=8,
                               kinds=("straggler", "lossy"), n_ranks=0)
    assert not d.has_stragglers()


# -------------------------------------------------- lossy links (GBN)
def test_lossy_gbn_factor_composes_per_hop():
    import jax.numpy as jnp

    from repro.core import gbn
    from repro.netsim import dataplane

    nl = 10
    loss = np.zeros(nl + 1, np.float32)
    loss[3], loss[7] = 0.01, 0.02
    # two flows, one sub-flow each with two fabric hops ([W, N, Hf]):
    # flow 0 crosses both lossy links, flow 1 is clean (-1 = hop absent)
    fab = jnp.asarray([[[3, 7]], [[-1, -1]]], jnp.int32)
    tx = jnp.asarray([8, 8], jnp.int32)
    rx = jnp.asarray([9, 9], jnp.int32)
    f = dataplane.lossy_gbn_factor(fab, tx, rx, jnp.asarray(loss),
                                   n_links=nl, window_pkts=64)
    assert f.shape == (2, 1)
    p = 1.0 - (1.0 - 0.01) * (1.0 - 0.02)  # survival composes per hop
    want = gbn.gbn_goodput_factor(jnp.float32(p), 64)
    np.testing.assert_allclose(float(f[0, 0]), float(want), rtol=1e-6)
    assert float(f[1, 0]) == 1.0  # clean path: no amplification

    # a lossy HOST link hits every sub-flow of the flow behind that NIC
    loss2 = np.zeros(nl + 1, np.float32)
    loss2[8] = 0.05
    f2 = dataplane.lossy_gbn_factor(fab, tx, rx, jnp.asarray(loss2),
                                    n_links=nl, window_pkts=64)
    want2 = gbn.gbn_goodput_factor(jnp.float32(0.05), 64)
    np.testing.assert_allclose(np.asarray(f2),
                               float(want2) * np.ones((2, 1)), rtol=1e-6)


def test_lossy_link_inflates_fct_same_program():
    from repro.netsim import sweep, topology, workloads
    from repro.netsim.engine import SimConfig

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    tr = workloads.poisson_trace(workloads.TraceConfig(
        workload="fixed:5e5", load=0.3, duration_s=1e-3, n_hosts=topo.n_hosts,
        host_bw=40e9, seed=5, hosts_per_leaf=2))
    cfg = SimConfig(scheme="ecmp", duration_s=1e-3)
    cap = np.asarray(topo.capacity, np.float32).copy()
    zeros = np.zeros(topo.n_links + 1, np.float32)
    lossy = zeros.copy()
    lossy[:2 * 4] = 0.005  # every leaf->spine link drops 0.5%

    r_clean, _ = sweep.run_one(topo, cfg, tr, capacity=cap, loss=zeros)
    before = sweep.cache_stats()["builds"]
    r_lossy, _ = sweep.run_one(topo, cfg, tr, capacity=cap, loss=lossy)
    assert sweep.cache_stats()["builds"] == before  # loss is a traced operand
    fin_c, fin_l = np.asarray(r_clean.finish), np.asarray(r_lossy.finish)
    done = np.isfinite(fin_c) & np.isfinite(fin_l)
    assert done.sum() >= 10
    # GBN rewinds stretch finish times: slower on average, never faster,
    # and loss can only censor MORE flows at the horizon
    assert fin_l[done].mean() > 1.02 * fin_c[done].mean()
    assert (fin_l[done] >= fin_c[done] - 1e-9).all()
    assert np.isfinite(fin_l).sum() <= np.isfinite(fin_c).sum()

    # a zero-loss vector is bit-identical to no loss operand at all
    r_none, _ = sweep.run_one(topo, cfg, tr, capacity=cap)
    np.testing.assert_array_equal(np.asarray(r_none.finish), fin_c)


# --------------------------------------------------- in-epoch replanning
def test_replan_chunk_paths_rules():
    from repro.dist.collectives import replan_chunk_paths

    dirs = (1, -1, 1, -1)
    paths = (0, 1, 2, 3, 0, 1)
    # path 1 dies: its chunks move to the OTHER -1 path; same-direction only
    out = replan_chunk_paths(paths, dirs, (False, True, False, False))
    assert out == (0, 3, 2, 3, 0, 3)
    # in-flight chunks never move, even off a dead path
    out = replan_chunk_paths(paths, dirs, (False, True, False, False),
                             in_flight=(1,))
    assert out == (0, 1, 2, 3, 0, 3)
    # both -1 paths dead: chunks STAY (in-order on a slow path beats a flip)
    out = replan_chunk_paths(paths, dirs, (False, True, False, True))
    assert out == paths
    # healthy chunks are never touched
    out = replan_chunk_paths(paths, dirs, (True, False, False, False))
    assert out[1:4] == (1, 2, 3) and out[5] == 1
    assert out[0] == 2 and out[4] == 2  # migrants round-robin over {2}


def test_pinned_plan_duck_types_path_plan():
    from repro.dist.collectives import PathPlan, PinnedPlan

    pp = PinnedPlan(n_chunks=3, directions=(1, -1), inactive=(False, True),
                    paths=(0, 0, 1))
    assert pp.chunk_paths() == (0, 0, 1) and pp.n_paths == 2
    with pytest.raises(AssertionError):
        PinnedPlan(n_chunks=2, directions=(1, -1), inactive=(False, False),
                   paths=(0, 5))  # out-of-range path
    base = PathPlan(n_chunks=3, directions=(1, -1), inactive=(False, True))
    assert base.chunk_paths() == (0, 0, 0)  # round-robin over survivors


def _split_steered_traces(n_paths=4, dead=(1,), rounds_a=3):
    """Mirror dist.cosim's replanning trace construction: segment a under
    the original plan, segment b under the pinned replanned plan with only
    dead-target QPs re-steered."""
    from repro.dist import collectives
    from repro.netsim import workloads

    plan = collectives.PathPlan(n_chunks=4,
                                directions=(1, -1, 1, -1)[:n_paths])
    hosts, n, gap = list(range(6)), 6, 1e-5
    rounds = 2 * (n - 1)
    active0 = list(range(n_paths))
    tgt = np.array([[active0[(i * plan.n_chunks + c) % len(active0)]
                     for i in range(n)] for c in range(plan.n_chunks)],
                   np.int32)
    inact2 = tuple(p in set(dead) for p in range(n_paths))
    pinned = collectives.PinnedPlan(
        n_chunks=plan.n_chunks, directions=tuple(plan.directions),
        inactive=inact2,
        paths=collectives.replan_chunk_paths(
            plan.chunk_paths(), tuple(plan.directions), inact2))
    surv = [p for p in active0 if p not in set(dead)] or [0]
    tgt_b, k = tgt.copy(), 0
    for c in range(plan.n_chunks):
        for i in range(n):
            if int(tgt[c, i]) in set(dead):
                tgt_b[c, i] = surv[k % len(surv)]
                k += 1
    kw = dict(link_bw=40e9, round_gap_s=gap, seed=3, steer_paths=n_paths)
    tr_a = workloads.collective_trace(plan, hosts, 1e6, rounds=rounds_a,
                                      steer_targets=tgt, **kw)
    tr_b = workloads.collective_trace(pinned, hosts, 1e6,
                                      rounds=rounds - rounds_a,
                                      start_s=rounds_a * gap,
                                      steer_targets=tgt_b, **kw)
    full = workloads.collective_trace(plan, hosts, 1e6, rounds=rounds, **kw)
    return (workloads.merge_traces(tr_a, tr_b), full, tgt, tgt_b,
            plan, rounds_a, rounds, n)


def test_replan_trace_never_reorders_inflight_qps():
    merged, full, tgt, tgt_b, plan, ra, rounds, n = _split_steered_traces()
    C = plan.n_chunks
    fid = merged.flow_id.reshape(rounds, C, n)
    fid_full = full.flow_id.reshape(rounds, C, n)
    src = merged.src.reshape(rounds, C, n)
    dst = merged.dst.reshape(rounds, C, n)

    # pre-cut rounds are BIT-IDENTICAL to the unreplanned collective: the
    # packets already on the wire cannot be renamed retroactively
    np.testing.assert_array_equal(fid[:ra], fid_full[:ra])

    # within each segment every QP keeps one fid for all its rounds
    for seg in (fid[:ra], fid[ra:]):
        assert (seg == seg[0]).all()

    # across the cut: surviving-target QPs keep their fid (same five-tuple
    # -> same fabric path -> no reorder); ONLY dead-target QPs re-steer
    changed = fid[ra] != fid[0]
    np.testing.assert_array_equal(changed, tgt != tgt_b)
    assert changed.any() and not changed.all()

    # ring directions never flip: per-(chunk, member) src/dst identical in
    # every round, before and after the cut
    assert (src == src[0]).all() and (dst == dst[0]).all()

    # arrivals stay monotone across the merge (segment b starts at the cut)
    arr = merged.arrivals.reshape(rounds, C, n)
    assert (np.diff(arr[:, 0, 0]) > 0).all()


def test_run_cosim_replans_and_improves_onset_epoch():
    from repro.dist import cosim
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(4, 4, 2, 100e9)
    camp = faults.FaultCampaign(events=(
        faults.LinkFlap(links=topology.spine_links(topo, 1), start_epoch=1,
                        end_epoch=3, duty=1.0, onset_frac=0.02, scale=0.0),))
    hosts = cosim.ring_hosts(topo, 6)
    kw = dict(scheme="ecmp", epochs=3, campaign=camp, phi_steps=2,
              n_chunks=4, seed=0, detect_delay_s=3.3e-5)
    h_re = cosim.run_cosim(topo, hosts, 1.2e6, replan=True, **kw)
    h_no = cosim.run_cosim(topo, hosts, 1.2e6, replan=False, **kw)
    r_re, r_no = h_re.records[1], h_no.records[1]
    assert r_re.replan_round > 0 and r_no.replan_round == -1
    # rerouting the tail rounds completes strictly more flows in the
    # fault epoch than riding the dead path to the horizon
    assert r_re.completion > r_no.completion
    # healthy epochs never replan, and the epoch after the onset routes
    # around the quarantined path entirely
    assert h_re.records[0].replan_round == -1
    assert h_re.records[2].completion == 1.0
    # campaign epochs reuse the one compiled program after epoch 0
    assert sum(r.new_builds for r in h_re.records[1:]) == 0


# -------------------------------------------------------- phi hysteresis
def test_hysteresis_doubles_phi_for_flappers():
    from repro.dist.elastic import LinkHealth

    # default cooldown_steps=0 is bit-exact legacy: phi never extends
    h0 = LinkHealth(n_paths=4, phi_steps=2)
    h0.report_slow(1, 0)
    h0.report_slow(1, 2)  # re-report exactly at expiry
    assert h0.phi_of(1) == 2 and h0.expiry(1) == 4

    h = LinkHealth(n_paths=4, phi_steps=2, cooldown_steps=2)
    h.report_slow(1, 0)
    assert h.expiry(1) == 2
    h.report_slow(1, 2)  # released and slow again inside cooldown: flapper
    assert h.phi_of(1) == 4 and h.expiry(1) == 6
    h.report_slow(1, 6)  # still flapping: doubles again
    assert h.phi_of(1) == 8 and h.expiry(1) == 14
    h.report_slow(1, 50)  # clean recovery, well past cooldown: reset
    assert h.phi_of(1) == 2 and h.expiry(1) == 52
    # a report while still quarantined refreshes but does NOT double
    h.report_slow(1, 51)
    assert h.phi_of(1) == 2 and h.expiry(1) == 53

    hc = LinkHealth(n_paths=4, phi_steps=2, cooldown_steps=2, max_phi_steps=4)
    hc.report_slow(0, 0)
    hc.report_slow(0, 2)
    hc.report_slow(0, 6)
    assert hc.phi_of(0) == 4  # capped

    # state round-trips through the journal snapshot
    h2 = LinkHealth(n_paths=4, phi_steps=2, cooldown_steps=2)
    h2.restore(h.state())
    assert h2.inactive(52) == h.inactive(52) and h2.phi_of(1) == h.phi_of(1)


# ------------------------------------------------------ straggler policy
def test_straggler_policy_quarantine_and_recovery():
    from repro.dist.elastic import StragglerPolicy

    p = StragglerPolicy(deadline_s=1.0, max_misses=3)
    assert p.observe(2, 0.9) == "ok"
    assert p.observe(2, 1.5) == "warn" and p.misses(2) == 1
    assert p.observe(2, 1.5) == "warn" and p.misses(2) == 2
    assert p.observe(2, 1.5) == "quarantine"
    assert p.quarantined() == (2,)
    assert p.observe(2, 1.5) == "quarantine"  # stays benched while slow
    assert p.observe(2, 0.5) == "ok"  # ONE on-time step recovers
    assert p.quarantined() == () and p.misses(2) == 0

    p.observe(0, 9.9)
    q = StragglerPolicy(deadline_s=1.0, max_misses=3)
    q.restore(p.state())
    assert q.misses(0) == 1 and q.quarantined() == p.quarantined()


def test_cosim_straggler_wiring():
    from repro.dist import cosim
    from repro.netsim import faults, topology

    topo = topology.leaf_spine(4, 4, 2, 100e9)
    camp = faults.FaultCampaign(events=(
        faults.Straggler(rank=3, slowdown=3.0, start_epoch=1, end_epoch=4),))
    hosts = cosim.ring_hosts(topo, 6)
    # horizon pinned so the 3x-stretched cadence OVERRUNS it (the honest
    # cost of a gating straggler) while the healthy cadence fits: the ring
    # gap is 16us here, so 10 rounds need 144us healthy vs 432us straggled
    h = cosim.run_cosim(topo, hosts, 1.2e6, scheme="ecmp", epochs=5,
                        campaign=camp, phi_steps=2, n_chunks=4, seed=0,
                        duration_s=2.4e-4)
    scale = [r.straggler_scale for r in h.records]
    quar = [r.straggler_quarantined for r in h.records]
    # epoch 1: the straggler gates the ring (first deadline miss = warn);
    # epoch 2: second miss hits max_misses=2 — benched, and the cadence
    # recovers WHILE the fault is still active; epoch 4: one on-time
    # observation un-benches it
    assert scale == [1.0, 3.0, 1.0, 1.0, 1.0]
    assert quar == [(), (), (3,), (3,), ()]
    # the stretched epoch pays for it in completion; the benched epoch
    # returns to the healthy cadence
    assert h.records[1].completion < 1.0 <= h.records[2].completion


# ----------------------------------------------------- crash-proof pool
def test_run_jobs_retry_salvage_timeout():
    import time

    from repro.netsim import sweep

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    def dead():
        raise ValueError("permanent wreck")

    def fine():
        return 42

    # retry: a transiently failing job succeeds within its retry budget
    out = sweep.run_jobs([flaky], workers=1, retries=2, backoff_s=0.0)
    assert out == ["ok"] and calls["n"] == 3

    # salvage: a permanently failing job yields a poisoned record AT ITS
    # INDEX; completed siblings are not burned
    out = sweep.run_jobs([fine, dead, fine], workers=2, retries=1,
                         backoff_s=0.0, salvage=True)
    assert out[0] == 42 and out[2] == 42
    fail = out[1]
    assert isinstance(fail, sweep.JobFailure) and fail.failed
    assert fail.index == 1 and fail.attempts == 2
    assert "permanent wreck" in fail.error and not fail.timed_out

    # without salvage the pool raises (legacy contract)
    with pytest.raises(ValueError):
        sweep.run_jobs([fine, dead], workers=2)

    # timeout: a hung job is censored as timed_out instead of wedging the
    # pool (the abandoned thread dies on its own; keep its sleep short so
    # interpreter shutdown doesn't wait on it either)
    def hung():
        time.sleep(5.0)

    t0 = time.time()
    out = sweep.run_jobs([fine, hung], workers=2, timeout_s=0.5, salvage=True)
    assert time.time() - t0 < 4.0
    assert out[0] == 42
    assert isinstance(out[1], sweep.JobFailure) and out[1].timed_out


def test_run_cosim_grid_salvages_poisoned_cells():
    from repro.dist import cosim
    from repro.netsim import sweep, topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    good = dict(topo=topo, hosts=cosim.ring_hosts(topo, 4),
                size_bytes=4e5, scheme="ecmp", epochs=2, phi_steps=2,
                n_chunks=4, seed=0)
    bad = dict(good, n_chunks=0)  # PathPlan asserts n_chunks >= 1
    out = cosim.run_cosim_grid([good, bad], workers=1, salvage=True)
    assert out[0].epochs == 2
    assert isinstance(out[1], sweep.JobFailure) and out[1].index == 1


# ------------------------------------------------------- epoch journal
def _journal_spec(topo, journal=None):
    from repro.dist import cosim

    return dict(topo=topo, hosts=cosim.ring_hosts(topo, 4), size_bytes=4e5,
                scheme="ecmp", epochs=4, phi_steps=2, n_chunks=4, seed=0,
                faults=(cosim.kill_spine(topo, 1, epoch=1, recover_epoch=2),),
                journal=journal)


def test_journal_resume_matches_uninterrupted(tmp_path):
    from repro.dist import cosim
    from repro.netsim import topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    jp = str(tmp_path / "campaign.jsonl")
    h_full = cosim.run_cosim(**_journal_spec(topo))
    cosim.run_cosim(**_journal_spec(topo, jp))

    # interrupt after epoch 1: keep header + two epoch lines, tear the rest
    lines = open(jp).read().splitlines()
    assert len(lines) == 5  # header + 4 epochs
    with open(jp, "w") as fh:
        fh.write("\n".join(lines[:3]) + "\n")
        fh.write(lines[3][: len(lines[3]) // 2])  # torn mid-write tail

    h_res = cosim.run_cosim(**_journal_spec(topo, jp))
    assert h_res.epochs == h_full.epochs
    for a, b in zip(h_full.records, h_res.records):
        assert a.epoch == b.epoch
        assert a.quarantined == b.quarantined
        assert a.completion == b.completion
        np.testing.assert_allclose(a.fct, b.fct, rtol=1e-6)
    assert h_res.final_plan.inactive == h_full.final_plan.inactive
    # the resumed journal is complete and parseable again
    lines = [json.loads(ln) for ln in open(jp)]
    assert [d.get("epoch") for d in lines[1:]] == [0, 1, 2, 3]


def test_journal_spec_mismatch_restarts(tmp_path):
    from repro.dist import cosim
    from repro.netsim import topology

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    jp = str(tmp_path / "campaign.jsonl")
    cosim.run_cosim(**_journal_spec(topo, jp))
    head = json.loads(open(jp).readline())

    spec = _journal_spec(topo, jp)
    spec["seed"] = 99  # different campaign: restart, don't splice
    h = cosim.run_cosim(**spec)
    assert h.epochs == 4
    head2 = json.loads(open(jp).readline())
    assert head2["spec"]["seed"] == 99 != head["spec"]["seed"]
