"""Hypothesis property tests for the chaos-campaign invariants (ISSUE 6):

  * phi-expiry hysteresis: under ANY report sequence, a LinkHealth with a
    cooldown quarantines at least as long as the legacy (cooldown 0) one —
    hysteresis may only extend windows, never release a path the legacy
    logic would still hold — and with cooldown 0 the two are bit-identical
    (the legacy-contract pin);
  * the effective phi never exceeds the cap and never drops below the
    base, and a clean (post-cooldown) re-report always resets to base;
  * in-epoch replanning (replan_chunk_paths): never moves an in-flight or
    healthy chunk, never flips a chunk's ring direction, and lands every
    migrant on a surviving path whenever one with the right direction
    exists.

Hypothesis is an optional dependency (not in the CI image) — these skip
when it is absent; seeded spot checks of the same properties run
unconditionally in tests/test_faults.py.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dist.collectives import replan_chunk_paths  # noqa: E402
from repro.dist.elastic import LinkHealth  # noqa: E402


@settings(max_examples=60, deadline=None)
@given(
    reports=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 40)),
                     max_size=30),
    phi=st.integers(1, 6),
    cooldown=st.integers(0, 6),
    cap_mult=st.integers(0, 10),
    probe=st.integers(0, 120),
)
def test_hysteresis_only_extends_quarantine(reports, phi, cooldown, cap_mult,
                                            probe):
    cap = phi * cap_mult  # a cap below phi_steps is rejected at init
    legacy = LinkHealth(n_paths=4, phi_steps=phi)
    hyst = LinkHealth(n_paths=4, phi_steps=phi, cooldown_steps=cooldown,
                      max_phi_steps=cap)
    for path, step in sorted(reports, key=lambda r: r[1]):
        legacy.report_slow(path, step)
        hyst.report_slow(path, step)
    for p in range(4):
        base, eff = legacy.phi_of(p), hyst.phi_of(p)
        assert eff >= base  # hysteresis never shortens a window
        if cap > 0:
            assert eff <= max(cap, phi)
        if cooldown == 0:  # bit-exact legacy: the co-sim release contract
            assert eff == base
            assert hyst.expiry(p) == legacy.expiry(p)
    # quarantine is monotone: any path the legacy logic holds at `probe`,
    # the hysteresis logic holds too
    for lq, hq in zip(legacy.inactive(probe), hyst.inactive(probe)):
        assert hq or not lq


@settings(max_examples=60, deadline=None)
@given(
    phi=st.integers(1, 6),
    cooldown=st.integers(1, 6),
    n_flaps=st.integers(1, 6),
    late=st.integers(7, 50),
)
def test_clean_recovery_resets_phi(phi, cooldown, n_flaps, late):
    h = LinkHealth(n_paths=1, phi_steps=phi, cooldown_steps=cooldown)
    step = 0
    h.report_slow(0, step)
    for _ in range(n_flaps):  # re-report exactly at each expiry: a flapper
        step = h.expiry(0)
        h.report_slow(0, step)
    assert h.phi_of(0) == phi * 2 ** n_flaps
    # next report lands well after expiry + cooldown: clean recovery
    h.report_slow(0, h.expiry(0) + cooldown + late)
    assert h.phi_of(0) == phi


@settings(max_examples=80, deadline=None)
@given(
    data=st.data(),
    n_paths=st.integers(1, 6),
    n_chunks=st.integers(1, 10),
)
def test_replan_respects_no_reordering_rules(data, n_paths, n_chunks):
    dirs = tuple(data.draw(st.sampled_from((1, -1)), label=f"dir{p}")
                 for p in range(n_paths))
    inactive = tuple(data.draw(st.booleans(), label=f"dead{p}")
                     for p in range(n_paths))
    paths = tuple(data.draw(st.integers(0, n_paths - 1), label=f"path{c}")
                  for c in range(n_chunks))
    in_flight = tuple(c for c in range(n_chunks)
                      if data.draw(st.booleans(), label=f"fly{c}"))
    out = replan_chunk_paths(paths, dirs, inactive, in_flight=in_flight)
    assert len(out) == n_chunks
    survivors_by_dir = {d: [p for p in range(n_paths)
                            if dirs[p] == d and not inactive[p]]
                        for d in (1, -1)}
    for c, (old, new) in enumerate(zip(paths, out)):
        if c in in_flight or not inactive[old]:
            assert new == old  # in-flight / healthy chunks never move
        elif survivors_by_dir[dirs[old]]:
            assert new in survivors_by_dir[dirs[old]]  # same-direction only
        else:
            assert new == old  # no same-direction survivor: stay, degraded
        assert dirs[new] == dirs[old]  # a direction flip IS a reorder
