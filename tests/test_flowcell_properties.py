"""Flowcell-granularity plans + explicit reordering-cost model (ISSUE 10).

Property harness for the token-based flowcell split below the chunk and
the go-back-N reordering amplification it pays for:

  * flowcell splitting CONSERVES bytes per (round, chunk, member) and
    inherits arrival/src/dst verbatim — only sizes, flow ids and the
    ``spray`` column change;
  * ``flowcells=1`` (and ``reorder_budget`` alone) degenerates BIT-EXACTLY
    to the classic chunk-granularity trace, and ``reorder=0.0`` on an
    unsprayed trace is bit-identical to ``reorder=None``;
  * ``dataplane.reorder_gbn_factor`` is always >= 1, exactly 1 whenever a
    flow straddles a single path, monotone in the budget, and exactly 1
    under an infinite budget;
  * dense oracle == compact engine on flowcell traces with the reorder
    operand, and for the ``flowlet_timeout`` WCMP scheme;
  * the hetero 100G/400G fabric factory wires its asymmetry into the flat
    capacity vector exactly where ``nic_links``/``fabric_links`` point,
    and ECMP five-tuple steering lands every flowcell on its planned path
    under the ENGINE's own hash (``flow_constants`` -> ``ecmp_paths``);
  * with flowcells disabled the fig12 sweep and the killed-spine co-sim
    reproduce the pre-flowcell goldens exactly (seeded sha twins).

Hypothesis is an optional dependency (not in the CI image) — the ``@given``
widenings skip when it is absent; the seeded spot checks of the same
properties run unconditionally.
"""
import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, routing
from repro.dist import collectives
from repro.netsim import compact, dataplane, engine, sweep, topology, \
    workloads

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI image has no hypothesis
    HAVE_HYPOTHESIS = False


def _plan(n_chunks=4, n_paths=4, inactive=None, fcells=1, budget=0.0):
    dirs = tuple(1 if p % 2 == 0 else -1 for p in range(n_paths))
    return collectives.PathPlan(n_chunks=n_chunks, directions=dirs,
                                inactive=inactive, flowcells=fcells,
                                reorder_budget=budget)


def _traces(fcells, *, inactive=None, steer_paths=None, n_chunks=4,
            n_paths=4, seed=3):
    hosts = [0, 4, 8, 12]
    kw = dict(link_bw=100e9, round_gap_s=1e-4, seed=seed,
              steer_paths=steer_paths)
    base = workloads.collective_trace(
        _plan(n_chunks, n_paths, inactive), hosts, 4e6, **kw)
    fc = workloads.collective_trace(
        _plan(n_chunks, n_paths, inactive, fcells), hosts, 4e6, **kw)
    return base, fc


# --------------------------------------------- trace-level conservation
@pytest.mark.parametrize("fcells,steer", [(2, None), (3, None), (3, 4),
                                          (5, 4), (8, None)])
def test_flowcell_split_conserves_chunk_bytes(fcells, steer):
    """Cells of one (round, chunk, member) QP sum to the chunk segment and
    inherit its arrival/src/dst — the split only changes granularity."""
    base, fc = _traces(fcells, steer_paths=steer)
    assert fc.sizes.size == base.sizes.size * fcells
    np.testing.assert_allclose(fc.sizes.reshape(-1, fcells).sum(axis=1),
                               base.sizes, rtol=1e-6)
    for field in ("arrivals", "src", "dst"):
        grouped = getattr(fc, field).reshape(-1, fcells)
        assert (grouped == grouped[:, :1]).all()
        np.testing.assert_array_equal(grouped[:, 0], getattr(base, field))
    # distinct five-tuples per cell (each cell is its own QP stream)
    fid = fc.flow_id.reshape(-1, fcells)
    assert all(len(set(row.tolist())) == fcells for row in fid)
    assert np.array_equal(np.unique(base.spray), [1])
    assert np.array_equal(np.unique(fc.spray), [min(fcells, 4)])


def test_flowcell_spray_counts_active_paths_only():
    """A quarantined path shrinks the straddle count: spray is
    min(flowcells, n_active), not min(flowcells, n_paths)."""
    inactive = (False, True, False, True)
    _, fc = _traces(4, inactive=inactive, steer_paths=4)
    assert np.array_equal(np.unique(fc.spray), [2])


def test_flowcells_one_is_bit_identical():
    """flowcells=1 (with or without a reorder budget on the plan) renders
    the EXACT pre-flowcell trace — all seven arrays, bit for bit."""
    base, _ = _traces(2)
    plan = _plan(fcells=1, budget=7.0)
    twin = workloads.collective_trace(plan, [0, 4, 8, 12], 4e6,
                                      link_bw=100e9, round_gap_s=1e-4,
                                      seed=3)
    for field in ("sizes", "arrivals", "src", "dst", "flow_id", "valid",
                  "spray"):
        np.testing.assert_array_equal(getattr(base, field),
                                      getattr(twin, field))


def test_flowcell_paths_tables():
    """Cell 0 of every chunk keeps the classic round-robin (PathPlan) or
    pinned (PinnedPlan) path; later cells walk the active set only."""
    inactive = (False, True, False, False)
    plan = _plan(inactive=inactive, fcells=3)
    tbl = plan.flowcell_paths()
    assert tuple(row[0] for row in tbl) == plan.chunk_paths()
    active = {0, 2, 3}
    assert all(p in active for row in tbl for p in row)
    assert _plan(fcells=1).flowcell_paths() == tuple(
        (p,) for p in _plan().chunk_paths())
    pinned = collectives.PinnedPlan(
        n_chunks=4, directions=(1, -1, 1, -1), inactive=inactive,
        paths=(3, 0, 2, 3), flowcells=2)
    tbl2 = pinned.flowcell_paths()
    assert tuple(row[0] for row in tbl2) == (3, 0, 2, 3)
    assert all(p in active for row in tbl2 for p in row)


# ------------------------------------------- reorder-factor invariants
def _factor(topo, pq, spray, rc0, budget):
    return np.asarray(dataplane.reorder_gbn_factor(
        topo, jnp.asarray(pq), jnp.asarray(spray), jnp.asarray(rc0),
        jnp.float32(budget), mtu_bytes=4096.0, jitter_mtus=4.0,
        window_pkts=64.0))


def _factor_instance(seed, F=64):
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    rng = np.random.default_rng(seed)
    pq = rng.uniform(0.0, 2e6, (F, topo.n_paths)).astype(np.float32)
    rc0 = rng.uniform(1e9, 100e9, F).astype(np.float32)
    spray = rng.integers(1, topo.n_paths + 1, F).astype(np.int32)
    return topo, pq, rc0, spray


def _check_factor_invariants(topo, pq, rc0, spray):
    amp0 = _factor(topo, pq, spray, rc0, 0.0)
    assert (amp0 >= 1.0).all()
    assert (amp0[spray <= 1] == 1.0).all()
    ones = np.ones(spray.shape, np.int32)
    assert (_factor(topo, pq, ones, rc0, 0.0) == 1.0).all()
    amp8 = _factor(topo, pq, spray, rc0, 8.0)
    assert (amp8 <= amp0 + 1e-6).all()  # budget only absorbs skew
    assert (_factor(topo, pq, spray, rc0, 1e9) == 1.0).all()


@pytest.mark.parametrize("seed", range(8))
def test_reorder_factor_invariants(seed):
    _check_factor_invariants(*_factor_instance(seed))


def test_reorder_factor_skew_monotone():
    """More inter-path skew can only cost more (same spray, same budget)."""
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    F = 32
    rc0 = np.full(F, 25e9, np.float32)
    spray = np.full(F, 4, np.int32)
    flat = np.full((F, topo.n_paths), 1e6, np.float32)
    skewed = flat.copy()
    skewed[:, 0] += 4e6  # one hot path
    assert (_factor(topo, skewed, spray, rc0, 0.0)
            >= _factor(topo, flat, spray, rc0, 0.0) - 1e-6).all()


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), F=st.integers(1, 200))
    def test_reorder_factor_invariants_hyp(seed, F):
        topo, pq, rc0, spray = _factor_instance(seed, F=F)
        _check_factor_invariants(topo, pq, rc0, spray)

    @settings(max_examples=15, deadline=None)
    @given(fcells=st.integers(2, 12), n_chunks=st.integers(1, 6),
           seed=st.integers(0, 2**16), steered=st.booleans())
    def test_flowcell_split_conserves_bytes_hyp(fcells, n_chunks, seed,
                                                steered):
        base, fc = _traces(fcells, steer_paths=4 if steered else None,
                           n_chunks=n_chunks, seed=seed)
        assert fc.sizes.size == base.sizes.size * fcells
        np.testing.assert_allclose(fc.sizes.reshape(-1, fcells).sum(axis=1),
                                   base.sizes, rtol=1e-6)
        np.testing.assert_array_equal(fc.arrivals.reshape(-1, fcells)[:, 0],
                                      base.arrivals)


# -------------------------------------------------- engine equivalences
def test_reorder_zero_budget_noop_on_unsprayed_trace():
    """The reorder operand must be behaviorally invisible when no flow
    straddles paths: reorder=0.0 on an all-ones-spray trace is bit-exact
    against reorder=None (the factor is EXACTLY 1 there, not just ~1)."""
    topo = topology.leaf_spine(2, 4, 4, 100e9)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=0.6, duration_s=0.8e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=5,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=2 * 4 * 100e9))
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=3e-3)
    r_none, _ = sweep.run_one(topo, cfg, trace)
    r_zero, _ = sweep.run_one(topo, cfg, trace, reorder=0.0)
    np.testing.assert_array_equal(np.asarray(r_none.finish),
                                  np.asarray(r_zero.finish))


@pytest.mark.parametrize("scheme", ["seqbalance", "ecmp"])
def test_dense_compact_agree_on_flowcell_reorder(scheme):
    """Cached-route compact step == recompute-route dense step with the
    spray column populated and the reorder operand live."""
    topo = topology.leaf_spine(2, 4, 4, 100e9)
    plan = _plan(fcells=3)
    trace = workloads.collective_trace(plan, [0, 1, 16, 17], 2e6,
                                       link_bw=100e9, round_gap_s=2e-4,
                                       seed=1, steer_paths=topo.n_paths)
    cfg = engine.SimConfig(scheme=scheme, duration_s=2e-3)
    st_dense, _ = engine.simulate(topo, cfg, trace, reorder=2.0)
    st_comp, _ = compact.simulate_compact(topo, cfg, trace, reorder=2.0)
    assert st_comp.spill_steps == 0
    fd = np.asarray(st_dense.finish)
    np.testing.assert_array_equal(np.isfinite(fd),
                                  np.isfinite(st_comp.finish))
    done = np.isfinite(fd)
    np.testing.assert_array_equal(st_comp.finish[done], fd[done])


def test_dense_compact_agree_flowlet_timeout_hetero():
    """The WCMP flowlet scheme must agree across engines on the asymmetric
    fabric (the compact engine recomputes weights from the traced capacity
    schedule; with a static topology that is the same vector)."""
    topo = topology.hetero_leaf_spine(2, 4, 4, 40e9, 160e9, n_fast_spines=1)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=0.5, duration_s=0.8e-3,
        n_hosts=topo.n_hosts, host_bw=40e9, seed=2,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=2 * 4 * 40e9))
    cfg = engine.SimConfig(scheme="flowlet_timeout", duration_s=3e-3)
    st_dense, _ = engine.simulate(topo, cfg, trace)
    st_comp, _ = compact.simulate_compact(topo, cfg, trace)
    assert st_comp.spill_steps == 0
    fd = np.asarray(st_dense.finish)
    np.testing.assert_array_equal(np.isfinite(fd),
                                  np.isfinite(st_comp.finish))
    done = np.isfinite(fd)
    np.testing.assert_array_equal(st_comp.finish[done], fd[done])


# --------------------------------------------- hetero topology factory
def test_hetero_factory_capacity_layout():
    """The 400G planes sit exactly where up[l,s]/down[s,l] say they do, and
    nic_links/fabric_links point flows at the asymmetric capacities."""
    L, S, hpl = 4, 4, 4
    topo = topology.hetero_leaf_spine(L, S, hpl, 100e9, 400e9,
                                      n_fast_spines=2)
    cap = np.asarray(topo.capacity)
    for leaf in range(L):
        for s in range(S):
            want = 400e9 if s >= S - 2 else 100e9
            assert cap[leaf * S + s] == np.float32(want)  # up[l, s]
            assert cap[L * S + s * L + leaf] == np.float32(want)  # down[s,l]
    tx, rx = (np.asarray(a) for a in topo.nic_links(0, 15))
    assert cap[int(tx)] == np.float32(100e9)  # hosts stay at slow_bw
    assert cap[int(rx)] == np.float32(100e9)
    fab_fast = np.asarray(topo.fabric_links(0, 1, S - 1))
    fab_slow = np.asarray(topo.fabric_links(0, 1, 0))
    assert (cap[fab_fast] == np.float32(400e9)).all()
    assert (cap[fab_slow] == np.float32(100e9)).all()
    # WCMP weights derived from these uplinks favor the fast planes 4:1
    w = np.asarray(baselines.wcmp_weights(
        jnp.asarray(cap[topo.uplink_ids[0]])))
    np.testing.assert_allclose(w, [0.1, 0.1, 0.4, 0.4], rtol=1e-6)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


def test_hetero_steering_lands_flowcells_on_planned_paths():
    """On the mixed-speed fabric, every flowcell's steered flow id must
    land on its planned path under the ENGINE's own five-tuple hash
    (flow_constants -> ecmp_paths) — the flowcell_paths round-robin,
    diversified per member, repeated every round."""
    topo = topology.hetero_leaf_spine(4, 4, 4, 100e9, 400e9,
                                      n_fast_spines=1)
    P = topo.n_paths
    fcells = 3
    inactive = (False, False, True, False)  # quarantine a slow plane
    plan = _plan(inactive=inactive, fcells=fcells)
    hosts = [0, 4, 8, 12]
    tr = workloads.collective_trace(plan, hosts, 2e6, link_bw=100e9,
                                    round_gap_s=1e-4, seed=0,
                                    steer_paths=P)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=1e-3)
    fc = engine.flow_constants(topo, cfg, jnp.asarray(tr.sizes),
                               jnp.asarray(tr.src), jnp.asarray(tr.dst),
                               jnp.asarray(tr.flow_id))
    realized = np.asarray(routing.ecmp_paths(*fc.f5, P))
    active = [p for p in range(P) if not inactive[p]]
    n, n_chunks, A = len(hosts), plan.n_chunks, len(active)
    per_round = [active[(i * n_chunks + c + j) % A]
                 for c in range(n_chunks) for i in range(n)
                 for j in range(fcells)]
    expect = np.asarray(per_round * (2 * (n - 1)), np.int32)
    np.testing.assert_array_equal(realized, expect)
    assert 2 not in realized  # the quarantined plane carries nothing


# ------------------------------------- degenerate sha-golden twin pins
def test_flowcell_disabled_fig12_bit_identical():
    """The fig12 sweep with the flowcell plumbing in its default state
    (reorder=None) reproduces the pre-flowcell golden exactly — the 7th
    trace column and the operand gating must be dead code there."""
    from tests.test_adaptive_dt import FIG12_GOLD, _fig12_trace

    topo = topology.sim_2tier()
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=10e-3,
                           uplink_sample_every=10)
    res, _ = sweep.run_one(topo, cfg, _fig12_trace(topo), reorder=None)
    f = np.asarray(res.finish)
    sha, fsum, cnp = FIG12_GOLD["seqbalance"]
    assert hashlib.sha1(f.tobytes()).hexdigest()[:16] == sha
    assert float(f[np.isfinite(f)].sum()) == fsum
    assert float(res.cnp_pkts) == cnp


def test_flowcell_disabled_cosim_bit_identical():
    """Killed-spine co-sim with flowcells=1 / reorder_budget=None passed
    EXPLICITLY (plans stamped, kwargs threaded) matches the pre-flowcell
    golden epoch for epoch."""
    from repro.dist import cosim
    from tests.test_adaptive_dt import COSIM_GOLD

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    hosts = cosim.ring_hosts(topo, 8)
    h = cosim.run_cosim(
        topo, hosts, 4e6, scheme="seqbalance", epochs=4, phi_steps=2,
        n_chunks=4, seed=0, flowcells=1, reorder_budget=None,
        faults=(cosim.kill_spine(topo, 2, epoch=1, recover_epoch=3),))
    assert [r.fct_p99_s for r in h.records] == COSIM_GOLD["p99"]
    assert [r.fct_p50_s for r in h.records] == COSIM_GOLD["p50"]
    assert [r.quarantined for r in h.records] == COSIM_GOLD["quarantined"]
    assert h.convergence_epoch(1) == COSIM_GOLD["conv"]


def test_flowcell_spec_key_only_when_used(tmp_path):
    """Journal compatibility: the ``flowcell`` spec entry exists only when
    the feature is on — pre-flowcell journals keep matching."""
    import json

    from repro.dist import cosim

    topo = topology.leaf_spine(2, 4, 2, 100e9)
    hosts = cosim.ring_hosts(topo, 4)
    j_off = tmp_path / "off.jsonl"
    j_on = tmp_path / "on.jsonl"
    cosim.run_cosim(topo, hosts, 1e6, scheme="ecmp", epochs=1, n_chunks=2,
                    journal=str(j_off))
    cosim.run_cosim(topo, hosts, 1e6, scheme="ecmp", epochs=1, n_chunks=2,
                    journal=str(j_on), flowcells=2, reorder_budget=4.0)
    head_off = json.loads(j_off.read_text().splitlines()[0])
    head_on = json.loads(j_on.read_text().splitlines()[0])
    assert "flowcell" not in head_off["spec"]
    assert head_on["spec"]["flowcell"] == dict(flowcells=2,
                                               reorder_budget=4.0)
