"""Per-kernel allclose vs the ref.py oracles, swept over shapes/dtypes
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa, linkload as ll, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("S,H,K,hd", [(128, 4, 4, 32), (256, 4, 2, 64), (256, 8, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(S, H, K, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, K, hd), dtype)
    v = _rand(ks[2], (B, S, K, hd), dtype)
    out = fa.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window", [0, 32, 100])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, hd = 1, 256, 2, 32
    q, k, v = (_rand(ks[i], (B, S, H, hd), jnp.float32) for i in range(3))
    out = fa.flash_attention(q, k, v, window=window, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("softcap", [0.0, 10.0, 50.0])
def test_flash_attention_softcap(softcap):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, hd = 1, 128, 2, 32
    q, k, v = (_rand(ks[i], (B, S, H, hd), jnp.float32) for i in range(3))
    out = fa.flash_attention(q, k, v, softcap=softcap, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_flash_attention_block_shape_invariance():
    """Different BlockSpec tilings must give identical results."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, hd = 1, 256, 2, 32
    q, k, v = (_rand(ks[i], (B, S, H, hd), jnp.float32) for i in range(3))
    o1 = fa.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = fa.flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("n,hops,L", [(100, 2, 50), (1000, 6, 200), (513, 4, 300)])
def test_linkload_sweep(n, hops, L):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    lid = jax.random.randint(ks[0], (n, hops), -1, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[1], (n,)) * 1e9
    queue = jax.random.uniform(ks[2], (L,)) * 2e6
    cap = jnp.full((L,), 4e10)
    l1, q1, m1 = ll.linkload(lid, rates, queue, cap, n_links=L, interpret=True)
    l2, q2, m2 = ref.linkload_ref(lid, rates, L, 400e3, 1600e3, 0.2, queue, cap, 10e-6)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1.0)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)


def test_linkload_drop_sentinel():
    """-1 hops must not contribute anywhere."""
    lid = jnp.array([[0, -1], [1, -1]], jnp.int32)
    rates = jnp.array([5.0, 7.0])
    queue = jnp.zeros((3,))
    cap = jnp.full((3,), 1e12)
    l1, _, _ = ll.linkload(lid, rates, queue, cap, n_links=3, interpret=True)
    np.testing.assert_allclose(np.asarray(l1), [5.0, 7.0, 0.0], atol=1e-6)
