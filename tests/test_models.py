"""Per-arch smoke tests (reduced configs) + decode/train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model


def make_batch(cfg, B=2, S=64):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S + 1), 1, cfg.vocab).astype(jnp.int32)
    batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = registry.get_config(arch, reduced=True).replace(dtype="float32")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward_train, static_argnums=1)(params, cfg, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    def lf(p):
        return model.loss_fn(p, cfg, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(lf))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0, "gradients are zero or NaN"


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = registry.get_config(arch, reduced=True).replace(dtype="float32")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, cache = jax.jit(model.prefill, static_argnums=(1, 3))(params, cfg, batch, 96)
    assert logits.shape == (2, cfg.vocab) and bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step, static_argnums=1)(params, cfg, tok, cache)
    assert logits2.shape == (2, cfg.vocab) and bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-2b", "qwen3-32b",
                                  "recurrentgemma-9b", "xlstm-1.3b"])
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode of token S must equal the full forward at S+1 —
    the KV-cache/ring-buffer/recurrent-state paths agree with the parallel
    path (the model-level no-reordering invariant)."""
    cfg = registry.get_config(arch, reduced=True).replace(dtype="float32")
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 1, cfg.vocab).astype(jnp.int32)
    batch = {"tokens": toks[:, :S]}
    _, cache = model.prefill(params, cfg, batch, S + 8)
    dec_logits, _ = model.decode_step(params, cfg, toks[:, S:S+1], cache)
    full_logits, _ = model.forward_train(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits[:, S]), atol=2e-3, rtol=2e-3
    )


def test_moe_losses_present_and_balanced_routing_possible():
    cfg = registry.get_config("deepseek-moe-16b", reduced=True).replace(dtype="float32")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, m = jax.jit(lambda p: model.loss_fn(p, cfg, batch), )(params)
    assert float(m["aux"]) > 0.0  # load-balance + z losses wired in


def test_gemma2_softcap_bounds_logits():
    cfg = registry.get_config("gemma2-2b", reduced=True).replace(dtype="float32")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, _ = model.forward_train(params, cfg, batch)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-3  # logit softcap


def test_long_context_archs_have_o1_state():
    """xLSTM / RecurrentGemma decode state must not grow with context."""
    for arch in registry.LONG_CONTEXT_ARCHS:
        cfg = registry.get_config(arch, reduced=True)
        c1 = jax.eval_shape(lambda: model.init_cache(None, cfg, 1, 1024))
        c2 = jax.eval_shape(lambda: model.init_cache(None, cfg, 1, 65536))
        s1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
        s2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
        # attention ring buffers are window-capped; recurrent state is O(1)
        assert s2 <= s1 * 8, (arch, s1, s2)
