"""Integration + property tests for the fluid network simulator."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.netsim import engine, metrics, topology, workloads
from repro.netsim.dcqcn import DCQCNParams


def small_topo():
    return topology.leaf_spine(2, 4, 4, 100e9)


def small_trace(topo, load=0.5, dur=1.5e-3, wl="alistorage", seed=0):
    return workloads.poisson_trace(workloads.TraceConfig(
        workload=wl, load=load, duration_s=dur, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=seed, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=2 * 4 * 100e9,
    ))


def run(topo, trace, scheme="seqbalance", dur=6e-3, **kw):
    cfg = engine.SimConfig(scheme=scheme, duration_s=dur, **kw)
    return engine.simulate(topo, cfg, trace), cfg


def test_conservation_all_bytes_delivered():
    """Every completed flow delivered exactly its size (no byte created or
    destroyed by the fluid model)."""
    topo = small_topo()
    trace = small_trace(topo)
    (st, outs), _ = run(topo, trace)
    done = np.isfinite(np.asarray(st.finish))
    assert done.any()
    rem = np.asarray(st.remaining).sum(-1)
    np.testing.assert_allclose(rem[done], 0.0, atol=1.0)
    # and goodput integral roughly equals delivered bytes
    delivered = (trace.sizes * done).sum()
    good = np.asarray(outs.goodput_total).sum() * 10e-6 / 8.0
    assert good >= delivered * 0.9


def test_fct_positive_and_after_arrival():
    topo = small_topo()
    trace = small_trace(topo)
    (st, _), _ = run(topo, trace)
    fin = np.asarray(st.finish)
    done = np.isfinite(fin)
    assert (fin[done] >= trace.arrivals[done]).all()


def test_letflow_conga_collapse_to_ecmp_under_rdma():
    """Paper Fig. 1 consequence: no flowlet gaps at RDMA rates, so flowlet
    schemes never reroute and match ECMP exactly."""
    topo = small_topo()
    trace = small_trace(topo)
    res = {}
    for scheme in ("ecmp", "letflow", "conga"):
        (st, _), _ = run(topo, trace, scheme)
        res[scheme] = np.asarray(st.finish)
    np.testing.assert_allclose(res["letflow"], res["ecmp"], rtol=1e-6)
    np.testing.assert_allclose(res["conga"], res["ecmp"], rtol=1e-6)


def test_seqbalance_beats_ecmp_elephant_regime():
    """The paper's motivating traffic mode: few large flows, low entropy."""
    topo = topology.leaf_spine(4, 8, 8, 100e9)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="fixed:10e6", load=0.6, duration_s=6e-3, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=3, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=4 * 8 * 100e9,
    ))
    (st_sb, out_sb), _ = run(topo, trace, "seqbalance", dur=25e-3)
    (st_ec, out_ec), _ = run(topo, trace, "ecmp", dur=25e-3)
    s_sb = metrics.fct_stats(st_sb, trace, topo, 100e9)
    s_ec = metrics.fct_stats(st_ec, trace, topo, 100e9)
    assert s_sb["avg_slowdown"] < s_ec["avg_slowdown"]
    imb_sb = np.median(metrics.throughput_imbalance(out_sb))
    imb_ec = np.median(metrics.throughput_imbalance(out_ec))
    assert imb_sb < imb_ec  # Fig. 7/13: much better balance


def test_drill_pays_gbn_penalty_under_load():
    topo = small_topo()
    trace = small_trace(topo, load=0.7, wl="websearch", dur=2e-3)
    (st_dr, _), _ = run(topo, trace, "drill", dur=10e-3)
    (st_ec, _), _ = run(topo, trace, "ecmp", dur=10e-3)
    s_dr = metrics.fct_stats(st_dr, trace, topo, 100e9)
    s_ec = metrics.fct_stats(st_ec, trace, topo, 100e9)
    assert s_dr["avg_slowdown"] > s_ec["avg_slowdown"]


def test_asymmetric_seqbalance_uses_fat_path():
    topo = topology.testbed_asymmetric()
    pairs = [(i, 3 + i) for i in range(3) for _ in range(4)]
    trace = workloads.permanent_senders_trace(pairs, [0.0] * 12, 2e8)
    dc40 = DCQCNParams(kmin_bytes=160e3, kmax_bytes=520e3, r_ai=400e6, min_rate=400e6)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=8e-3, dcqcn=dc40)
    st, outs = engine.simulate(topo, cfg, trace)
    up = np.asarray(outs.uplink_load)[:, 0, :]  # leaf0, 3 paths
    late = up[400:].mean(0)
    assert late[2] > late[:2].max()  # 80G path carries the most traffic


def test_congestion_packets_negligible_when_balanced():
    """Table II: a balanced fabric generates ~no Congestion Packets."""
    topo = topology.testbed_symmetric()
    pairs = [(0, 3), (1, 4)]
    trace = workloads.permanent_senders_trace(pairs, [0.0, 0.0], 1e8)
    dc40 = DCQCNParams(kmin_bytes=160e3, kmax_bytes=520e3)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=5e-3, dcqcn=dc40)
    st, _ = engine.simulate(topo, cfg, trace)
    bw = metrics.congestion_packet_bandwidth(st, 5e-3)
    assert bw < 0.01 * 40e9  # well under 1% of a link


def test_three_tier_topology_runs_all_supported_schemes():
    topo = topology.three_tier(n_tor=4, n_agg=4, n_core=2, hosts_per_tor=2)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=0.4, duration_s=1e-3, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=0, hosts_per_leaf=topo.hosts_per_leaf,
    ))
    for scheme in ("ecmp", "letflow", "seqbalance"):
        (st, _), _ = run(topo, trace, scheme, dur=4e-3)
        assert np.isfinite(np.asarray(st.finish)).any(), scheme


def test_workload_sampler_statistics():
    cdf = workloads.WORKLOADS["websearch"]
    rng = np.random.default_rng(0)
    s = workloads.sample_sizes(cdf, 20000, rng)
    assert abs(np.mean(s) / workloads.cdf_mean(cdf) - 1) < 0.15
    assert s.min() >= cdf[0, 0] and s.max() <= cdf[-1, 0]


def test_trace_inter_rack_only():
    topo = small_topo()
    tr = small_trace(topo)
    src_leaf = tr.src // topo.hosts_per_leaf
    dst_leaf = tr.dst // topo.hosts_per_leaf
    assert (src_leaf[tr.valid] != dst_leaf[tr.valid]).all()
