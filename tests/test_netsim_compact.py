"""Active-window engine vs dense oracle, fused dataplane vs ref oracle,
and vmapped sweep vs serial runs (DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import linkload as ll, ref
from repro.netsim import compact, dataplane, engine, sweep, topology, workloads


def small_topo():
    return topology.leaf_spine(2, 4, 4, 100e9)


def small_trace(topo, load=0.5, dur=1.5e-3, wl="alistorage", seed=0):
    return workloads.poisson_trace(workloads.TraceConfig(
        workload=wl, load=load, duration_s=dur, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=seed, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=2 * 4 * 100e9,
    ))


# ------------------------------------------- compacted vs dense equivalence
@pytest.mark.parametrize("scheme", engine.SCHEMES)
def test_compact_matches_dense_oracle(scheme):
    """The active-window engine is the same physics over a compacted state:
    finish times must agree with the dense oracle exactly (both engines cut
    transfers at the same DONE_EPS_BYTES threshold, so no underflow-tail
    float sensitivity is left)."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme=scheme, duration_s=6e-3)
    st_dense, _ = engine.simulate(topo, cfg, trace)
    st_comp, _ = compact.simulate_compact(topo, cfg, trace)
    fd = np.asarray(st_dense.finish)
    fc = st_comp.finish
    assert st_comp.spill_steps == 0
    np.testing.assert_array_equal(np.isfinite(fd), np.isfinite(fc))
    done = np.isfinite(fd)
    assert done.any()
    np.testing.assert_array_equal(fc[done], fd[done])
    np.testing.assert_allclose(
        float(st_comp.cnp_pkts), float(st_dense.cnp_pkts), rtol=1e-5, atol=1e-3
    )


def test_compact_window_independent():
    """With no spill, results must not depend on the window size."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=6e-3)
    a, _ = compact.simulate_compact(topo, cfg, trace, window_slots=512)
    b, _ = compact.simulate_compact(topo, cfg, trace, window_slots=1024)
    assert a.spill_steps == 0 and b.spill_steps == 0
    np.testing.assert_array_equal(a.finish, b.finish)


def test_compact_tiny_window_spills_but_degrades_gracefully():
    """An undersized window must not lose flows: admission is delayed (NIC
    backpressure), spill_steps reports it, and nearly as many flows still
    complete as in an amply-sized run."""
    topo = small_topo()
    trace = small_trace(topo, dur=0.5e-3)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=8e-3)
    st, _ = compact.simulate_compact(topo, cfg, trace, window_slots=16)
    ample, _ = compact.simulate_compact(topo, cfg, trace, window_slots=2048)
    assert st.spill_steps > 0 and ample.spill_steps == 0
    done_small = np.isfinite(st.finish[trace.valid]).mean()
    done_ample = np.isfinite(ample.finish[trace.valid]).mean()
    assert done_small >= 0.9 * done_ample > 0.5


def test_sweep_retries_spill_to_match_oracle():
    """run_batch re-plans an undersized window until spill-free, so its
    output always matches the dense oracle."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=6e-3)
    res, _ = sweep.run_batch(topo, cfg, [trace], window_slots=64)
    assert res[0].spill_steps == 0
    assert res[0].window_slots > 64
    st_dense, _ = engine.simulate(topo, cfg, trace)
    fd = np.asarray(st_dense.finish)
    done = np.isfinite(fd)
    np.testing.assert_array_equal(res[0].finish[done], fd[done])


# ------------------------------------------------ fused dataplane kernels
@pytest.mark.parametrize("n,hops,L", [(100, 6, 50), (513, 4, 30), (64, 2, 5)])
def test_linkload_cascade_kernel_vs_ref(n, hops, L):
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    lid = jax.random.randint(ks[0], (n, hops), -1, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[1], (n,)) * 1e9
    queue = jax.random.uniform(ks[2], (L,)) * 2e6
    cap = jnp.full((L,), 4e9)
    qmask = jnp.ones((L,)).at[:2].set(0.0)
    a1, q1, m1, t1 = ll.linkload_cascade(
        lid, rates, queue, cap, qmask, n_links=L, block_n=64, interpret=True
    )
    a2, q2, m2, t2 = ref.linkload_cascade_ref(
        lid, rates, L, 400e3, 1600e3, 0.2, queue, cap, qmask, 10e-6
    )
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1.0)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=2e-5, atol=1e-2)


def test_dataplane_pallas_backend_matches_xla():
    """cascade() must give the same answer through the Pallas kernel
    (interpret mode on CPU) and the XLA segment-sum path."""
    topo = small_topo()
    key = jax.random.PRNGKey(7)
    n = 96
    src = jax.random.randint(key, (n,), 0, topo.n_hosts)
    dst = (src + 4) % topo.n_hosts
    path = jax.random.randint(key, (n,), 0, topo.n_paths)
    links = topo.subflow_links(src, dst, path)
    rates = jax.random.uniform(key, (n,)) * 50e9
    queue = jnp.zeros((topo.n_links + 1,))
    qmask = dataplane.queue_mask_for(topo)
    kw = dict(n_links=topo.n_links, kmin=400e3, kmax=1600e3, pmax=0.2,
              dt=10e-6, qmax_bytes=8e6)
    out_x = dataplane.cascade(links, rates, queue, topo.capacity, qmask,
                              backend="xla", **kw)
    out_p = dataplane.cascade(links, rates, queue, topo.capacity, qmask,
                              backend="pallas_interpret", **kw)
    for x, p in zip(out_x, out_p):
        np.testing.assert_allclose(np.asarray(x), np.asarray(p), rtol=2e-5, atol=1e-2)


def test_dense_engine_uses_same_dataplane():
    """The dense oracle routes through netsim/dataplane.py: a one-step run
    must reproduce linkload_cascade_ref on its own offered load."""
    topo = small_topo()
    trace = small_trace(topo, dur=0.3e-3)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=10e-6)  # single step
    st, outs = engine.simulate(topo, cfg, trace)
    assert np.asarray(outs.uplink_load).shape[0] == 1


# --------------------------------------------------------- vmapped sweeps
def test_sweep_vmapped_equals_serial():
    topo = small_topo()
    traces = [small_trace(topo, seed=s) for s in (0, 1, 2)]
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=4e-3)
    batch, bouts = sweep.run_batch(topo, cfg, traces)
    for i, t in enumerate(traces):
        single, souts = sweep.run_one(topo, cfg, t)
        np.testing.assert_array_equal(batch[i].finish, single.finish)
        np.testing.assert_allclose(
            np.asarray(bouts[i].max_queue), np.asarray(souts.max_queue)
        )
        np.testing.assert_allclose(
            np.asarray(bouts[i].uplink_load), np.asarray(souts.uplink_load)
        )


def test_sweep_groups_mixed_sizes():
    """Traces of very different sizes run in separate shape buckets but
    return in input order, each matching its own serial run."""
    topo = small_topo()
    big = small_trace(topo, dur=1.5e-3)
    tiny = small_trace(topo, wl="websearch", dur=0.3e-3, seed=5)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=4e-3)
    batch, _ = sweep.run_batch(topo, cfg, [tiny, big])
    for res, t in zip(batch, [tiny, big]):
        single, _ = sweep.run_one(topo, cfg, t)
        np.testing.assert_array_equal(res.finish, single.finish)


def test_sweep_jobs_match_serial():
    topo = small_topo()
    trace = small_trace(topo)
    cfgs = [engine.SimConfig(scheme=s, duration_s=4e-3) for s in ("ecmp", "letflow")]
    jobs = [(topo, c, [trace]) for c in cfgs]
    out = sweep.run_jobs(jobs, workers=2)
    for cfg, (res, _) in zip(cfgs, out):
        single, _ = sweep.run_one(topo, cfg, trace)
        np.testing.assert_array_equal(res[0].finish, single.finish)


def test_max_concurrency_bound_sane():
    topo = small_topo()
    trace = small_trace(topo)
    arrays, _, F = compact.sort_trace(trace)
    w = compact.max_concurrency_bound(arrays[0], arrays[1], arrays[5], 100e9)
    assert 0 < w
    a = compact.max_admits_per_step(arrays[1], arrays[5], 10e-6)
    assert 1 <= a <= F
