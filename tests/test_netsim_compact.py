"""Active-window engine vs dense oracle, fused dataplane vs ref oracle,
and vmapped sweep vs serial runs (DESIGN.md §9/§10)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import linkload as ll, ref
from repro.netsim import compact, dataplane, engine, sweep, topology, workloads


def small_topo():
    return topology.leaf_spine(2, 4, 4, 100e9)


def small_trace(topo, load=0.5, dur=1.5e-3, wl="alistorage", seed=0):
    return workloads.poisson_trace(workloads.TraceConfig(
        workload=wl, load=load, duration_s=dur, n_hosts=topo.n_hosts,
        host_bw=100e9, seed=seed, hosts_per_leaf=topo.hosts_per_leaf,
        load_base_bw=2 * 4 * 100e9,
    ))


# ------------------------------------------- compacted vs dense equivalence
@pytest.mark.parametrize("scheme", engine.SCHEMES)
def test_compact_matches_dense_oracle(scheme):
    """The active-window engine is the same physics over a compacted state:
    finish times must agree with the dense oracle exactly (both engines cut
    transfers at the same DONE_EPS_BYTES threshold, so no underflow-tail
    float sensitivity is left)."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme=scheme, duration_s=6e-3)
    st_dense, _ = engine.simulate(topo, cfg, trace)
    st_comp, _ = compact.simulate_compact(topo, cfg, trace)
    fd = np.asarray(st_dense.finish)
    fc = st_comp.finish
    assert st_comp.spill_steps == 0
    np.testing.assert_array_equal(np.isfinite(fd), np.isfinite(fc))
    done = np.isfinite(fd)
    assert done.any()
    np.testing.assert_array_equal(fc[done], fd[done])
    np.testing.assert_allclose(
        float(st_comp.cnp_pkts), float(st_dense.cnp_pkts), rtol=1e-5, atol=1e-3
    )


def test_compact_window_independent():
    """With no spill, results must not depend on the window size."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=6e-3)
    a, _ = compact.simulate_compact(topo, cfg, trace, window_slots=512)
    b, _ = compact.simulate_compact(topo, cfg, trace, window_slots=1024)
    assert a.spill_steps == 0 and b.spill_steps == 0
    np.testing.assert_array_equal(a.finish, b.finish)


def test_compact_tiny_window_spills_but_degrades_gracefully():
    """An undersized window must not lose flows: admission is delayed (NIC
    backpressure), spill_steps reports it, and nearly as many flows still
    complete as in an amply-sized run."""
    topo = small_topo()
    trace = small_trace(topo, dur=0.5e-3)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=8e-3)
    st, _ = compact.simulate_compact(topo, cfg, trace, window_slots=16)
    ample, _ = compact.simulate_compact(topo, cfg, trace, window_slots=2048)
    assert st.spill_steps > 0 and ample.spill_steps == 0
    done_small = np.isfinite(st.finish[trace.valid]).mean()
    done_ample = np.isfinite(ample.finish[trace.valid]).mean()
    assert done_small >= 0.9 * done_ample > 0.5


def test_sweep_retries_spill_to_match_oracle():
    """run_batch re-plans an undersized window until spill-free, so its
    output always matches the dense oracle."""
    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=6e-3)
    res, _ = sweep.run_batch(topo, cfg, [trace], window_slots=64)
    assert res[0].spill_steps == 0
    assert res[0].window_slots > 64
    st_dense, _ = engine.simulate(topo, cfg, trace)
    fd = np.asarray(st_dense.finish)
    done = np.isfinite(fd)
    np.testing.assert_array_equal(res[0].finish[done], fd[done])


def test_compact_results_chunk_invariant():
    """The K-step scan chunking (and its early-exit-at-chunk-boundary
    semantics) must not change any result: skipped steps are exact no-ops."""
    import dataclasses

    topo = small_topo()
    trace = small_trace(topo)
    # 601-step horizon: no divisor near either chunk size, so both runs
    # exercise the lax.cond'd tail block too
    for dur in (6e-3, 6.01e-3):
        cfg = engine.SimConfig(scheme="seqbalance", duration_s=dur,
                               chunk_steps=32)
        odd = dataclasses.replace(cfg, chunk_steps=7)
        a, oa = compact.simulate_compact(topo, cfg, trace)
        b, ob = compact.simulate_compact(topo, odd, trace)
        np.testing.assert_array_equal(a.finish, b.finish)
        np.testing.assert_array_equal(
            np.asarray(oa.uplink_load), np.asarray(ob.uplink_load))
        np.testing.assert_array_equal(
            np.asarray(oa.goodput_total), np.asarray(ob.goodput_total))


def test_compact_sampled_uplink_outputs():
    """cfg.uplink_sample_every folds the imbalance window-averaging into
    the scan: finish times stay identical and the sampled trace equals the
    window means of the full one."""
    import dataclasses

    topo = small_topo()
    trace = small_trace(topo)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=4e-3)
    samp = dataclasses.replace(cfg, uplink_sample_every=10)
    a, oa = compact.simulate_compact(topo, cfg, trace)
    b, ob = compact.simulate_compact(topo, samp, trace)
    np.testing.assert_array_equal(a.finish, b.finish)
    up = np.asarray(oa.uplink_load)
    T = up.shape[0] // 10 * 10
    want = up[:T].reshape(-1, 10, *up.shape[1:]).mean(axis=1)
    got = np.asarray(ob.uplink_load)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e3)
    # per-step scalars stay full-resolution either way
    np.testing.assert_array_equal(
        np.asarray(oa.goodput_total), np.asarray(ob.goodput_total))
    from repro.netsim import metrics

    imb_full = metrics.throughput_imbalance(oa, sample_every=10)
    imb_samp = metrics.throughput_imbalance(ob, sample_every=10, trace_stride=10)
    np.testing.assert_allclose(imb_samp, imb_full, rtol=1e-4)


# ------------------------------------------------ fused dataplane kernels
@pytest.mark.parametrize("n,hops,L", [(100, 6, 50), (513, 4, 30), (64, 2, 5)])
def test_linkload_cascade_kernel_vs_ref(n, hops, L):
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    lid = jax.random.randint(ks[0], (n, hops), -1, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[1], (n,)) * 1e9
    queue = jax.random.uniform(ks[2], (L,)) * 2e6
    cap = jnp.full((L,), 4e9)
    qmask = jnp.ones((L,)).at[:2].set(0.0)
    a1, q1, m1, t1 = ll.linkload_cascade(
        lid, rates, queue, cap, qmask, n_links=L, block_n=64, interpret=True
    )
    a2, q2, m2, t2 = ref.linkload_cascade_ref(
        lid, rates, L, 400e3, 1600e3, 0.2, queue, cap, qmask, 10e-6
    )
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1.0)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=2e-5, atol=1e-2)


@pytest.mark.parametrize("n,n_sub,hf,L", [(100, 4, 2, 50), (513, 1, 4, 30),
                                          (64, 2, 2, 5)])
def test_linkload_cascade_tiered_kernel_vs_ref(n, n_sub, hf, L):
    """Interpret-mode check of the NIC-tiered kernel layout."""
    ks = jax.random.split(jax.random.PRNGKey(n), 6)
    fab = jax.random.randint(ks[0], (n, n_sub, hf), -1, L).astype(jnp.int32)
    tx = jax.random.randint(ks[1], (n,), 0, L).astype(jnp.int32)
    rx = jax.random.randint(ks[2], (n,), 0, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[3], (n, n_sub)) * 1e9
    queue = jax.random.uniform(ks[4], (L,)) * 2e6
    cap = jnp.full((L,), 4e9)
    qmask = jnp.ones((L,)).at[:2].set(0.0)
    a1, q1, m1, t1 = ll.linkload_cascade_tiered(
        fab, tx, rx, rates, queue, cap, qmask, n_links=L, block_n=64,
        interpret=True,
    )
    a2, q2, m2, t2 = ref.linkload_cascade_tiered_ref(
        fab, tx, rx, rates, L, 400e3, 1600e3, 0.2, queue, cap, qmask, 10e-6
    )
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1.0)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=2e-5, atol=1e-2)


@pytest.mark.parametrize("kind,seed", [("leaf_spine", 0), ("three_tier", 1),
                                       ("leaf_spine", 2)])
def test_cascade_nic_matches_flat(kind, seed):
    """The NIC-tiered cascade is the same physics as the flat one — only
    the summation grouping differs (pre-reduce over N on the host hops), so
    results agree to float round-off on both topology families."""
    if kind == "leaf_spine":
        topo = topology.leaf_spine(2, 4, 4, 100e9)
    else:
        topo = topology.three_tier(4, 4, 2, 3, bw_tor_agg=400e9,
                                   bw_agg_core=100e9)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    n, N = 128, 4
    src = jax.random.randint(ks[0], (n,), 0, topo.n_hosts)
    dst = (src + 1 + jax.random.randint(ks[1], (n,), 0, topo.n_hosts - 1)) \
        % topo.n_hosts
    path = jax.random.randint(ks[2], (n, N), 0, topo.n_paths)
    links = topo.subflow_links(src[:, None], dst[:, None], path)
    tx, rx = topo.nic_links(src, dst)
    hpl = topo.hosts_per_leaf
    fab = topo.fabric_links((src // hpl)[:, None], (dst // hpl)[:, None], path)
    # the flat hop vector and the tiered builders describe the same routes
    np.testing.assert_array_equal(np.asarray(links[:, 0, 0]), np.asarray(tx))
    np.testing.assert_array_equal(np.asarray(links[:, 0, -1]), np.asarray(rx))
    np.testing.assert_array_equal(np.asarray(links[:, :, 1:-1]), np.asarray(fab))
    rates = jax.random.uniform(ks[3], (n, N)) * 50e9
    queue = jnp.zeros((topo.n_links + 1,))
    qmask = dataplane.queue_mask_for(topo)
    kw = dict(n_links=topo.n_links, kmin=400e3, kmax=1600e3, pmax=0.2,
              dt=10e-6, qmax_bytes=8e6)
    out_flat = dataplane.cascade(links, rates, queue, topo.capacity, qmask,
                                 backend="xla", **kw)
    out_nic = dataplane.cascade_nic(fab, tx, rx, rates, queue, topo.capacity,
                                    qmask, backend="xla", **kw)
    out_nic_p = dataplane.cascade_nic(fab, tx, rx, rates, queue, topo.capacity,
                                      qmask, backend="pallas_interpret", **kw)
    tols = [dict(rtol=2e-5, atol=1e-3), dict(rtol=1e-4, atol=1.0),
            dict(atol=1e-6), dict(rtol=2e-5, atol=1e-2)]
    for x, y, tol in zip(out_flat, out_nic, tols):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)
    for x, y, tol in zip(out_nic, out_nic_p, tols):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)
    pm = jnp.concatenate(
        [jax.random.uniform(key, (topo.n_links,)) * 0.3, jnp.zeros((1,))])
    ps1, pf1 = dataplane.subflow_mark_probs(links, pm, topo.n_links)
    ps2, pf2 = dataplane.subflow_mark_probs_nic(fab, tx, rx, pm, topo.n_links)
    np.testing.assert_allclose(np.asarray(ps1), np.asarray(ps2), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(pf1), np.asarray(pf2), rtol=1e-5,
                               atol=1e-7)


def test_dataplane_pallas_backend_matches_xla():
    """cascade() must give the same answer through the Pallas kernel
    (interpret mode on CPU) and the XLA segment-sum path."""
    topo = small_topo()
    key = jax.random.PRNGKey(7)
    n = 96
    src = jax.random.randint(key, (n,), 0, topo.n_hosts)
    dst = (src + 4) % topo.n_hosts
    path = jax.random.randint(key, (n,), 0, topo.n_paths)
    links = topo.subflow_links(src, dst, path)
    rates = jax.random.uniform(key, (n,)) * 50e9
    queue = jnp.zeros((topo.n_links + 1,))
    qmask = dataplane.queue_mask_for(topo)
    kw = dict(n_links=topo.n_links, kmin=400e3, kmax=1600e3, pmax=0.2,
              dt=10e-6, qmax_bytes=8e6)
    out_x = dataplane.cascade(links, rates, queue, topo.capacity, qmask,
                              backend="xla", **kw)
    out_p = dataplane.cascade(links, rates, queue, topo.capacity, qmask,
                              backend="pallas_interpret", **kw)
    for x, p in zip(out_x, out_p):
        np.testing.assert_allclose(np.asarray(x), np.asarray(p), rtol=2e-5, atol=1e-2)


def test_dense_engine_uses_same_dataplane():
    """The dense oracle routes through netsim/dataplane.py: a one-step run
    must reproduce linkload_cascade_ref on its own offered load."""
    topo = small_topo()
    trace = small_trace(topo, dur=0.3e-3)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=10e-6)  # single step
    st, outs = engine.simulate(topo, cfg, trace)
    assert np.asarray(outs.uplink_load).shape[0] == 1


# --------------------------------------------------------- vmapped sweeps
@pytest.mark.parametrize("mode", ["persim", "vmap"])
def test_sweep_batch_equals_serial(mode, monkeypatch):
    """Both single-device dispatch modes (per-sim B=1 loop on cpu, one
    jitted vmap elsewhere) must reproduce the serial per-trace runs."""
    monkeypatch.setenv("REPRO_SWEEP_BATCH", mode)
    topo = small_topo()
    traces = [small_trace(topo, seed=s) for s in (0, 1, 2)]
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=4e-3)
    batch, bouts = sweep.run_batch(topo, cfg, traces)
    for i, t in enumerate(traces):
        single, souts = sweep.run_one(topo, cfg, t)
        np.testing.assert_array_equal(batch[i].finish, single.finish)
        np.testing.assert_allclose(
            np.asarray(bouts[i].max_queue), np.asarray(souts.max_queue)
        )
        np.testing.assert_allclose(
            np.asarray(bouts[i].uplink_load), np.asarray(souts.uplink_load)
        )


def test_sweep_groups_mixed_sizes():
    """Traces of very different sizes run in separate shape buckets but
    return in input order, each matching its own serial run."""
    topo = small_topo()
    big = small_trace(topo, dur=1.5e-3)
    tiny = small_trace(topo, wl="websearch", dur=0.3e-3, seed=5)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=4e-3)
    batch, _ = sweep.run_batch(topo, cfg, [tiny, big])
    for res, t in zip(batch, [tiny, big]):
        single, _ = sweep.run_one(topo, cfg, t)
        np.testing.assert_array_equal(res.finish, single.finish)


def test_sweep_jobs_match_serial():
    topo = small_topo()
    trace = small_trace(topo)
    cfgs = [engine.SimConfig(scheme=s, duration_s=4e-3) for s in ("ecmp", "letflow")]
    jobs = [(topo, c, [trace]) for c in cfgs]
    out = sweep.run_jobs(jobs, workers=2)
    for cfg, (res, _) in zip(cfgs, out):
        single, _ = sweep.run_one(topo, cfg, trace)
        np.testing.assert_array_equal(res[0].finish, single.finish)


def test_sweep_sharded_matches_single_device():
    """With >1 local device the runner dispatches pmap-of-vmap shards; the
    results must equal the single-device vmap path bit-for-bit.  CPU CI has
    one device, so the sharded path runs in a subprocess with XLA's forced
    host-device partitioning."""
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=2 " + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax
assert jax.local_device_count() == 2
from repro.netsim import engine, sweep, topology, workloads

topo = topology.leaf_spine(2, 4, 4, 100e9)
traces = [workloads.poisson_trace(workloads.TraceConfig(
    workload="alistorage", load=0.5, duration_s=0.8e-3, n_hosts=topo.n_hosts,
    host_bw=100e9, seed=s, hosts_per_leaf=topo.hosts_per_leaf,
    load_base_bw=2 * 4 * 100e9)) for s in (0, 1, 2)]
cfg = engine.SimConfig(scheme="ecmp", duration_s=2.5e-3)
sharded, souts = sweep.run_batch(topo, cfg, traces)  # B=3 padded onto 2 devices
os.environ["REPRO_SWEEP_DEVICES"] = "1"  # force the plain vmap path
single, vouts = sweep.run_batch(topo, cfg, traces)
for i in range(3):
    np.testing.assert_array_equal(sharded[i].finish, single[i].finish)
    np.testing.assert_allclose(
        np.asarray(souts[i].max_queue), np.asarray(vouts[i].max_queue))
print("SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH"),) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")]
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


def test_profile_phases_smoke():
    """--profile machinery: every phase times out positive and the fused
    step is reported alongside."""
    from repro.netsim import profile

    topo = small_topo()
    trace = small_trace(topo, dur=0.5e-3)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=2e-3)
    times = profile.profile_phases(topo, cfg, trace, warm_steps=20, iters=3)
    for phase in ("admit", "cascade", "dcqcn", "finish", "step_fused"):
        assert times[phase] > 0.0
    assert times["window_slots"] >= 8


def test_max_concurrency_bound_sane():
    topo = small_topo()
    trace = small_trace(topo)
    arrays, _, F = compact.sort_trace(trace)
    w = compact.max_concurrency_bound(arrays[0], arrays[1], arrays[5], 100e9)
    assert 0 < w
    a = compact.max_admits_per_step(arrays[1], arrays[5], 10e-6)
    assert 1 <= a <= F
