"""Hypothesis property tests for the perf-critical equivalences (ISSUE 3):

  * NIC-tiered cascade == flat cascade on arbitrary random "topologies"
    (random link ids, hop-absence masks, rates, queues) — the tiered
    layout is a pure regrouping of the same segment-sums;
  * tiered Pallas kernel (interpret mode) == its jnp oracle on the same
    random instances;
  * cached-route compact step == recompute-route dense step: the admit-time
    SlotCache must be behaviorally invisible (routes are immutable once
    placed), so finish times agree exactly across random traces.

Hypothesis is an optional dependency (not in the CI image) — these skip
when it is absent; seeded spot checks of the same properties run
unconditionally in tests/test_netsim_compact.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import linkload as ll, ref  # noqa: E402
from repro.netsim import compact, dataplane, engine, topology, workloads  # noqa: E402


def _random_instance(seed, n, n_sub, hf, L):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    fab = jax.random.randint(ks[0], (n, n_sub, hf), -1, L).astype(jnp.int32)
    tx = jax.random.randint(ks[1], (n,), 0, L).astype(jnp.int32)
    rx = jax.random.randint(ks[2], (n,), 0, L).astype(jnp.int32)
    rates = jax.random.uniform(ks[3], (n, n_sub)) * 1e9
    queue = jax.random.uniform(ks[4], (L + 1,)) * 2e6
    queue = queue.at[L].set(0.0)
    cap = jnp.concatenate([jnp.full((L,), 4e9), jnp.full((1,), 1e30)])
    qmask = jnp.ones((L + 1,)).at[:2].set(0.0).at[L].set(0.0)
    return fab, tx, rx, rates, queue, cap, qmask


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 200),
    n_sub=st.integers(1, 6),
    hf=st.integers(1, 4),
    L=st.integers(3, 60),
)
def test_tiered_cascade_equals_flat(seed, n, n_sub, hf, L):
    fab, tx, rx, rates, queue, cap, qmask = _random_instance(seed, n, n_sub, hf, L)
    links = jnp.concatenate(
        [jnp.broadcast_to(tx[:, None, None], (n, n_sub, 1)), fab,
         jnp.broadcast_to(rx[:, None, None], (n, n_sub, 1))], axis=-1)
    kw = dict(n_links=L, kmin=400e3, kmax=1600e3, pmax=0.2, dt=10e-6,
              qmax_bytes=8e6)
    out_flat = dataplane.cascade(links, rates, queue, cap, qmask,
                                 backend="xla", **kw)
    out_nic = dataplane.cascade_nic(fab, tx, rx, rates, queue, cap, qmask,
                                    backend="xla", **kw)
    tols = [dict(rtol=2e-5, atol=1e-3), dict(rtol=1e-4, atol=1.0),
            dict(atol=1e-6), dict(rtol=2e-5, atol=1e-2)]
    for x, y, tol in zip(out_flat, out_nic, tols):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)
    pm = jnp.concatenate(
        [jax.random.uniform(jax.random.PRNGKey(seed), (L,)) * 0.5,
         jnp.zeros((1,))])
    ps1, pf1 = dataplane.subflow_mark_probs(links, pm, L)
    ps2, pf2 = dataplane.subflow_mark_probs_nic(fab, tx, rx, pm, L)
    np.testing.assert_allclose(np.asarray(ps1), np.asarray(ps2),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(pf1), np.asarray(pf2),
                               rtol=1e-5, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 150),
    n_sub=st.integers(1, 4),
    hf=st.integers(1, 4),
    L=st.integers(3, 50),
)
def test_tiered_kernel_interpret_equals_ref(seed, n, n_sub, hf, L):
    fab, tx, rx, rates, queue, cap, qmask = _random_instance(seed, n, n_sub, hf, L)
    a1, q1, m1, t1 = ll.linkload_cascade_tiered(
        fab, tx, rx, rates, queue[:L], cap[:L], qmask[:L], n_links=L,
        block_n=64, interpret=True,
    )
    a2, q2, m2, t2 = ref.linkload_cascade_tiered_ref(
        fab, tx, rx, rates, L, 400e3, 1600e3, 0.2, queue[:L], cap[:L],
        qmask[:L], 10e-6,
    )
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1.0)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=2e-5, atol=1e-2)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    load=st.sampled_from([0.4, 0.7]),
    scheme=st.sampled_from(engine.SCHEMES),
)
def test_cached_route_step_equals_recompute(seed, load, scheme):
    """The compact engine snapshots routes/link-ids at admission; the dense
    oracle re-derives them from the topology every step.  Random traces
    must finish at identical times (spill-free => bit-exact)."""
    topo = topology.leaf_spine(2, 4, 4, 100e9)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="alistorage", load=load, duration_s=0.8e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=seed,
        hosts_per_leaf=topo.hosts_per_leaf, load_base_bw=2 * 4 * 100e9,
    ))
    cfg = engine.SimConfig(scheme=scheme, duration_s=3e-3)
    st_dense, _ = engine.simulate(topo, cfg, trace)
    st_comp, _ = compact.simulate_compact(topo, cfg, trace)
    assert st_comp.spill_steps == 0
    fd = np.asarray(st_dense.finish)
    np.testing.assert_array_equal(np.isfinite(fd), np.isfinite(st_comp.finish))
    done = np.isfinite(fd)
    np.testing.assert_array_equal(st_comp.finish[done], fd[done])
