"""Observability plane (DESIGN.md §16): in-sim ring-buffer recorder
semantics (wraparound, chronology, quantiles), the recording-changes-
nothing bit-identity contract against the PR 7 seeded-twin goldens, the
zero-rebuild contract under the co-sim epoch loop, the flight-log schema
/ torn-tail reader, and the exporters (perfetto trace, epoch matrix)."""
import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.netsim import engine, sweep, topology, workloads
from tests.test_adaptive_dt import FIG12_GOLD, _collective, _fig12_trace


# ------------------------------------------------ ring-buffer semantics
def _fill(spec, n_uplinks, n_chunks, K=10):
    ring = obs.ring_init(spec, n_uplinks)
    for i in range(n_chunks):
        ring = obs.record_chunk(
            spec, ring, step0=jnp.int32(i * K), steps=jnp.int32(K),
            ff=jnp.bool_(i % 2 == 0), queue_max=jnp.float32(100.0 + i),
            queue_mean=jnp.float32(10.0 + i), cnp=jnp.float32(i),
            goodput=jnp.float32(1e9 * i),
            offered=jnp.full((n_uplinks,), 1e9 * (i + 1)),
            cap=jnp.full((n_uplinks,), 100e9),
            rc=jnp.arange(1.0, 6.0), active=jnp.ones(5, bool))
    return ring


def test_ring_wraparound_keeps_newest_chronological():
    spec = obs.RecordSpec(ring_chunks=4)
    d = obs.drain(spec, _fill(spec, 2, 10))
    assert d["chunks_recorded"] == 10 and d["chunks_kept"] == 4
    step0 = d["meta"][:, d["fields"].index("step0")]
    # the NEWEST 4 of 10 chunks, oldest-first — wraparound rotated out 0..5
    assert step0.tolist() == [60.0, 70.0, 80.0, 90.0]
    q = d["meta"][:, d["fields"].index("queue_max")]
    assert q.tolist() == [106.0, 107.0, 108.0, 109.0]
    assert d["uplink"].shape == (4, 2, 2)
    assert d["uplink"][-1, 0, 0] == pytest.approx(10e9)  # offered, chunk 9


def test_ring_no_wrap_partial_fill():
    spec = obs.RecordSpec(ring_chunks=8)
    d = obs.drain(spec, _fill(spec, 1, 3))
    assert d["chunks_recorded"] == 3 and d["chunks_kept"] == 3
    assert d["meta"][:, 0].tolist() == [0.0, 10.0, 20.0]


def test_rank_quantiles_and_summary():
    spec = obs.RecordSpec(ring_chunks=2, quantiles=(0.1, 0.5, 0.9))
    d = obs.drain(spec, _fill(spec, 2, 2))
    # rc = [1..5] all active: rank idx = clip(4*q) -> sorted[0]/[2]/[3]
    f = d["fields"]
    assert f[-3:] == ["rc_q10", "rc_q50", "rc_q90"]
    assert d["meta"][0, f.index("rc_q10")] == 1.0
    assert d["meta"][0, f.index("rc_q50")] == 3.0
    assert d["meta"][0, f.index("rc_q90")] == 4.0
    s = obs.epoch_summary(spec, d)
    json.dumps(s)  # flight-log bound: must be strict-JSON serializable
    assert s["chunks_recorded"] == 2 and s["ff_chunks"] == 1
    assert s["queue_max_bytes"] == 101.0
    assert len(s["uplink"]["util_mean"]) == 2
    assert s["chunks"]["step0"] == [0.0, 10.0]


def test_quantiles_all_inactive_are_zero():
    spec = obs.RecordSpec(ring_chunks=2, quantiles=(0.5,))
    ring = obs.ring_init(spec, 1)
    ring = obs.record_chunk(
        spec, ring, step0=jnp.int32(0), steps=jnp.int32(5),
        ff=jnp.bool_(False), queue_max=jnp.float32(0), queue_mean=jnp.float32(0),
        cnp=jnp.float32(0), goodput=jnp.float32(0), offered=jnp.zeros(1),
        cap=jnp.ones(1), rc=jnp.arange(5.0), active=jnp.zeros(5, bool))
    d = obs.drain(spec, ring)
    assert d["meta"][0, d["fields"].index("rc_q50")] == 0.0


# ------------------------------- recording changes nothing (bit identity)
def test_recording_bit_identical_fig12_golden():
    """A recorded run must land the EXACT PR 7 golden finish times — the
    ring buffer rides along, it never perturbs the dynamics (same pattern
    as the adaptive=False seeded-twin goldens)."""
    topo = topology.sim_2tier()
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=10e-3,
                           uplink_sample_every=10)
    res, _ = sweep.run_one(topo, cfg, _fig12_trace(topo),
                           record=obs.RecordSpec(ring_chunks=32))
    f = np.asarray(res.finish)
    sha, fsum, cnp = FIG12_GOLD["seqbalance"]
    assert hashlib.sha1(f.tobytes()).hexdigest()[:16] == sha
    assert float(f[np.isfinite(f)].sum()) == fsum
    assert float(res.cnp_pkts) == cnp
    assert res.ring is not None
    d = obs.drain(obs.RecordSpec(ring_chunks=32), res.ring)
    assert d["chunks_recorded"] > 0


def test_unrecorded_result_has_no_ring():
    topo = topology.leaf_spine(2, 2, 2, 100e9)
    cfg = engine.SimConfig(scheme="ecmp", duration_s=0.5e-3)
    trace = workloads.poisson_trace(workloads.TraceConfig(
        workload="websearch", load=0.4, duration_s=0.2e-3,
        n_hosts=topo.n_hosts, host_bw=100e9, seed=0,
        hosts_per_leaf=topo.hosts_per_leaf))
    res, _ = sweep.run_one(topo, cfg, trace)
    assert res.ring is None


def test_recording_wraparound_in_sim_keeps_tail():
    """Sim-level wraparound: a tiny ring on the long collective run must
    rotate out the oldest chunks but keep the FINAL chunk (the boundary
    chunk covering the end of the horizon)."""
    topo = topology.leaf_spine(4, 4, 4, 100e9)
    cfg = engine.SimConfig(scheme="seqbalance", duration_s=14e-3,
                           uplink_sample_every=10)
    trace = _collective(topo)
    small = obs.RecordSpec(ring_chunks=4)
    big = obs.RecordSpec(ring_chunks=256)
    res_s, _ = sweep.run_one(topo, cfg, trace, record=small)
    res_b, _ = sweep.run_one(topo, cfg, trace, record=big)
    d_s = obs.drain(small, res_s.ring)
    d_b = obs.drain(big, res_b.ring)
    assert d_s["chunks_recorded"] == d_b["chunks_recorded"] > 4
    assert d_b["chunks_kept"] == d_b["chunks_recorded"]
    assert d_s["chunks_kept"] == 4
    # the small ring's 4 rows are exactly the big drain's last 4 rows
    np.testing.assert_array_equal(d_s["meta"], d_b["meta"][-4:])
    last = d_b["meta"][-1]
    assert last[0] + last[1] == pytest.approx(
        d_b["meta"][0, 0] + d_b["meta"][:, 1].sum())  # covers the horizon end


# --------------------------------------- cosim: flight log, zero rebuilds
def test_cosim_recording_zero_rebuilds_and_flight(tmp_path):
    from repro.dist import cosim

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    hosts = cosim.ring_hosts(topo, 8)
    kw = dict(scheme="ecmp", epochs=3, phi_steps=2, n_chunks=4, seed=0,
              faults=(cosim.kill_spine(topo, 2, epoch=1),))
    fl = tmp_path / "flight.jsonl"
    rec = obs.RecordSpec(ring_chunks=32)
    h0 = cosim.run_cosim(topo, hosts, 4e6, **kw)
    h1 = cosim.run_cosim(topo, hosts, 4e6, record=rec, flight=str(fl), **kw)
    # driver observables bit-identical with recording on
    assert [r.fct_p99_s for r in h0.records] == \
        [r.fct_p99_s for r in h1.records]
    assert [r.quarantined for r in h0.records] == \
        [r.quarantined for r in h1.records]
    # the one-extra-executable contract: epoch 0 builds, nothing after
    assert sum(r.new_builds for r in h1.records[1:]) == 0
    assert all(r.insim is not None for r in h1.records)
    assert all(r.insim is None for r in h0.records)

    header, recs = obs.read_flight(str(fl))
    assert header["schema_version"] == obs.SCHEMA_VERSION
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "campaign" and kinds[-1] == "run_end"
    eps = [r for r in recs if r["kind"] == "epoch"]
    assert [r["epoch"] for r in eps] == [0, 1, 2]
    assert all(r["insim"]["chunks_recorded"] > 0 for r in eps)
    assert eps[1]["faults"][0]["kind"] == "FaultEvent"
    assert eps[0]["hot_uplinks"] and "util" in eps[0]["hot_uplinks"][0]
    assert recs[-1]["total_new_builds"] == sum(
        r.new_builds for r in h1.records)

    # exporters round-trip off the same file
    from repro.obs import trace_export
    from repro.obs.features import epoch_matrix

    out = tmp_path / "trace.json"
    trace = trace_export.export_chrome_trace(str(fl), str(out))
    names = {e.get("name") for e in trace["traceEvents"]}
    assert {"epoch 0", "epoch 1", "epoch 2"} <= names
    assert "FaultEvent" in names
    json.loads(out.read_text())  # strict JSON on disk
    m = epoch_matrix(str(fl))
    assert m["matrix"].shape == (3, topo.uplink_ids.size, len(m["features"]))
    assert m["epochs"] == [0, 1, 2]
    assert np.isfinite(m["matrix"]).all()


def test_flight_log_instance_shared_not_closed(tmp_path):
    from repro.dist import cosim

    topo = topology.leaf_spine(4, 4, 4, 100e9)
    hosts = cosim.ring_hosts(topo, 8)
    fl = obs.FlightLog(str(tmp_path / "shared.jsonl"), meta=dict(who="test"))
    cosim.run_cosim(topo, hosts, 4e6, scheme="ecmp", epochs=1, n_chunks=4,
                    seed=0, flight=fl)
    fl.event("custom", note="caller still owns the log")
    fl.close()
    header, recs = obs.read_flight(str(tmp_path / "shared.jsonl"))
    assert header["meta"]["who"] == "test"
    assert [r["kind"] for r in recs][-1] == "custom"


# ------------------------------------------------- flight-log schema
def test_flight_schema_version_shared_with_journal():
    from repro.dist import cosim

    assert obs.SCHEMA_VERSION == cosim.JOURNAL_SCHEMA_VERSION


def test_flight_reader_tolerates_torn_tail(tmp_path):
    p = tmp_path / "torn.jsonl"
    with obs.FlightLog(str(p)) as fl:
        fl.event("epoch", epoch=0)
        fl.event("epoch", epoch=1)
    with open(p, "a") as fh:
        fh.write('{"kind": "epoch", "epo')  # interrupted mid-write
    header, recs = obs.read_flight(str(p))
    assert len(recs) == 2 and recs[-1]["epoch"] == 1


def test_flight_reader_rejects_wrong_schema(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"journal": "flight", "schema_version": 999}) + "\n")
    with pytest.raises(obs.FlightLogError):
        obs.read_flight(str(p))
    (tmp_path / "empty.jsonl").write_text("")
    with pytest.raises(obs.FlightLogError):
        obs.read_flight(str(tmp_path / "empty.jsonl"))


def test_runmeta_keys_stable():
    m = obs.runmeta()
    assert set(m) == {"run_id", "git_sha", "host", "n_devices", "backend",
                      "time_utc"}
    assert obs.runmeta()["run_id"] == m["run_id"]  # per-process constant
    json.dumps(m)


# --------------------------------------------------- profile TimeUs
def test_time_us_is_float_with_stats():
    from repro.netsim.profile import TimeUs

    t = TimeUs([3.0, 1.0, 2.0])
    assert float(t) == 1.0 and t.min_us == 1.0  # min is the headline value
    assert t.mean_us == pytest.approx(2.0)
    assert t.std_us == pytest.approx(np.std([3.0, 1.0, 2.0]))
    assert round(t, 2) == 1.0 and t * 2 == 2.0  # still a float
    s = t.stats()
    assert s == dict(min_us=1.0, mean_us=2.0, std_us=round(t.std_us, 3),
                     iters=3)
    json.dumps(s)


def test_watchdog_transition_counters_roundtrip():
    from repro.dist.elastic import TelemetryWatchdog

    wd = TelemetryWatchdog(blackout_epochs=2)
    assert [wd.observe(n) for n in (3, 0, 0, 0, 5, 1)] == \
        ["ok", "silent", "safe", "safe", "recovered", "ok"]
    st = wd.state()
    assert st["transitions"] == dict(ok=2, silent=1, safe=2, recovered=1)
    wd2 = TelemetryWatchdog(blackout_epochs=2)
    wd2.restore(st)
    assert wd2.state() == st
    wd2.restore(dict(silent=0, safe=False))  # pre-counter journals: fine
