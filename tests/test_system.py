"""End-to-end system tests: training convergence, checkpoint/restart,
data-pipeline determinism, sharding-rule validity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import pipeline

from repro.dist import sharding
from repro.launch import steps
from repro.models import model
from repro.train import checkpoint, optimizer as opt_mod


def tiny_cfg():
    return registry.get_config("granite-3-8b", reduced=True).replace(dtype="float32")


def test_train_loop_loss_decreases():
    cfg = tiny_cfg()
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, global_batch=8, seq_len=64, seed=0)
    state = steps.init_state(jax.random.PRNGKey(0), cfg)
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step_fn = jax.jit(steps.make_train_step(cfg, ocfg))
    losses = []
    for i in range(40):
        batch = pipeline.batch_at(dcfg, i)
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses[:3] + losses[-3:]


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    cfg = tiny_cfg()
    state = steps.init_state(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, state)
    assert checkpoint.latest_step(d) == 7
    restored = checkpoint.restore(d, 7, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corruption must be detected
    files = [f for f in os.listdir(d + "/step_00000007") if f.endswith(".npy")]
    victim = sorted(files, key=lambda f: -os.path.getsize(os.path.join(d, "step_00000007", f)))[0]
    p = os.path.join(d, "step_00000007", victim)
    arr = np.load(p)
    flat = arr.reshape(-1).view(np.uint8).copy()
    flat[0] ^= 0xFF
    np.save(p, flat.view(arr.dtype).reshape(arr.shape))
    with pytest.raises(IOError):
        checkpoint.restore(d, 7, jax.eval_shape(lambda: state))


def test_checkpoint_restart_resumes_identically(tmp_path):
    """Crash/restart: resume from step k gives the SAME trajectory as the
    uninterrupted run (fault-tolerance invariant)."""
    cfg = tiny_cfg()
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, global_batch=4, seq_len=32, seed=1)
    ocfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(steps.make_train_step(cfg, ocfg))

    state = steps.init_state(jax.random.PRNGKey(0), cfg)
    for i in range(6):
        state, m1 = step_fn(state, pipeline.batch_at(dcfg, i))
        if i == 2:
            checkpoint.save(str(tmp_path), 2, state)

    state2 = checkpoint.restore(str(tmp_path), 2, jax.eval_shape(lambda: state))
    state2 = jax.tree.map(jnp.asarray, state2)
    for i in range(3, 6):  # skip-ahead: data is a pure function of step
        state2, m2 = step_fn(state2, pipeline.batch_at(dcfg, i))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)


def test_async_checkpoint_save(tmp_path):
    cfg = tiny_cfg()
    state = steps.init_state(jax.random.PRNGKey(0), cfg)
    t = checkpoint.save(str(tmp_path), 9, state, blocking=False)
    t.join(timeout=120)
    assert checkpoint.latest_step(str(tmp_path)) == 9


def test_data_pipeline_deterministic_and_skippable():
    dcfg = pipeline.DataConfig(vocab=1000, global_batch=4, seq_len=16, seed=3)
    b1 = pipeline.batch_at(dcfg, 42)
    b2 = pipeline.batch_at(dcfg, 42)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = pipeline.batch_at(dcfg, 43)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].min()) >= 1 and int(b1["tokens"].max()) < 1000
    assert b1["tokens"].shape == (4, 16) and b1["labels"].shape == (4, 16)


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_param_specs_are_valid_for_production_mesh(arch):
    """Every sharding rule must divide: validated against an ABSTRACT
    16x16 mesh (no devices needed)."""
    cfg = registry.get_config(arch)
    params = jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    specs = sharding.param_specs(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sflat = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    assert len(flat) == len(sflat)
    sizes = {"data": 16, "model": 16}
    for (path, leaf), spec in zip(flat, sflat):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = int(np.prod([sizes[a] for a in axes]))
            assert dim % div == 0, (jax.tree_util.keystr(path), leaf.shape, spec)


def test_fsdp_actually_shards_large_params():
    """The big 2D+ matrices must not end up fully replicated."""
    cfg = registry.get_config("qwen3-32b")
    params = jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    specs = sharding.param_specs(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sflat = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    replicated_big = 0
    for (path, leaf), spec in zip(flat, sflat):
        n = int(np.prod(leaf.shape))
        if n > 16 * 1024 * 1024 and all(a is None for a in tuple(spec)):
            replicated_big += n
    assert replicated_big == 0, f"{replicated_big} replicated big params"
