"""Seeded twins for the degraded-telemetry control plane (ISSUE 7):
TelemetryChannel mechanics, staleness-bounded admission, the blackout
watchdog state machine, versioned plan application, and the co-sim
driver's safe-mode fallback + journal schema v2.  The hypothesis
generalizations of the admission invariants live in
tests/test_telemetry_properties.py (optional dep); everything here runs
unconditionally.
"""
import json
import os

import numpy as np
import pytest

from repro.dist import collectives
from repro.dist.elastic import LinkHealth, TelemetryWatchdog
from repro.netsim.faults import FaultCampaign, LinkFlap, TelemetryChannel


# ------------------------------------------------------- channel mechanics
def test_perfect_channel_delivers_everything_in_order():
    ch = TelemetryChannel()
    for e in range(4):
        ch.send(("slow", e), e)
        ch.send(("hb", 0), e)
        assert ch.deliver(e) == [(("slow", e), e), (("hb", 0), e)]
    assert ch.sent == 8 and ch.delivered == 8 and ch.dropped == 0


def test_channel_delay_shifts_delivery_epochs():
    ch = TelemetryChannel(delay_epochs=2)
    ch.send(("slow", 1), 0)
    assert ch.deliver(0) == [] and ch.deliver(1) == []
    assert ch.deliver(2) == [(("slow", 1), 0)]  # origin stamp preserved
    assert ch.deliver(3) == []


def test_channel_loss_is_seeded_and_deterministic():
    def run(seed):
        ch = TelemetryChannel(loss=0.5, seed=seed)
        for e in range(40):
            ch.send(("slow", e), e)
        return tuple(p for p, _ in ch.deliver(100))

    assert run(3) == run(3)  # same seed, same fate
    assert run(3) != run(4)  # loss actually depends on the seed
    ch = TelemetryChannel(loss=0.5, seed=3)
    for e in range(40):
        ch.send(("slow", e), e)
    assert 0 < ch.dropped < 40  # neither lossless nor total blackout


def test_channel_duplication_and_reorder_are_seeded():
    ch = TelemetryChannel(dup=1.0, delay_epochs=0, seed=0)
    ch.send(("slow", 7), 0)
    got = ch.deliver(5)
    assert got.count((("slow", 7), 0)) == 2  # dup=1: exactly two copies
    a = TelemetryChannel(reorder=True, seed=9)
    b = TelemetryChannel(reorder=True, seed=9)
    for ch2 in (a, b):
        for i in range(6):
            ch2.send(("slow", i), 0)
    assert a.deliver(0) == b.deliver(0)  # reorder shuffle replays per seed


def test_channel_blackout_drops_sends_and_deliveries():
    # delay 2 straddles the [1, 4) window from both sides
    ch = TelemetryChannel(delay_epochs=2, blackout=(1, 4))
    ch.send(("slow", 0), 0)  # sent ok, arrives 2 = inside -> dropped
    ch.send(("slow", 1), 1)  # sent inside -> dropped
    ch.send(("slow", 2), 4)  # sent at 4 (window is half-open), arrives 6
    out = []
    for e in range(7):
        out.extend(ch.deliver(e))
    assert out == [(("slow", 2), 4)]
    assert ch.dropped == 2


def test_channel_state_restore_replays_bit_identically(tmp_path):
    def mk():
        return TelemetryChannel(loss=0.3, delay_epochs=1, jitter_epochs=1,
                                dup=0.3, reorder=True, seed=11)

    a = mk()
    for e in range(3):
        a.send(("slow", e), e)
        a.deliver(e)
    # snapshot through an actual JSON round-trip (the journal's spelling)
    snap = json.loads(json.dumps(a.state()))
    b = mk()
    b.restore(snap)
    for e in range(3, 8):
        a.send(("slow", e), e)
        b.send(("slow", e), e)
        assert a.deliver(e) == b.deliver(e)
    assert (a.sent, a.dropped, a.delivered) == (b.sent, b.dropped, b.delivered)


# --------------------------------------------- staleness-bounded admission
def test_admit_report_verdicts():
    h = LinkHealth(n_paths=4, phi_steps=3, max_staleness_epochs=2)
    assert h.admit_report(1, origin_epoch=5, now_epoch=5) == "admitted"
    assert h.admit_report(1, origin_epoch=5, now_epoch=6) == "duplicate"
    assert h.admit_report(1, origin_epoch=4, now_epoch=6) == "admitted"
    assert h.admit_report(2, origin_epoch=1, now_epoch=6) == "stale"
    # stale and duplicate admissions leave the quarantine state untouched
    assert h.inactive(6) == (False, True, False, False)
    # quarantine keys on the DELIVERY epoch (admitted at 6 -> held to 8)
    assert h.expiry(1) == 6 + 3


def test_admit_report_unbounded_by_default():
    h = LinkHealth(n_paths=2, phi_steps=2)
    assert h.admit_report(0, origin_epoch=0, now_epoch=50) == "admitted"


def test_duplicate_admission_does_not_trip_flap_hysteresis():
    # same (path, origin) delivered twice across the cooldown boundary: the
    # duplicate must not double the phi window
    h = LinkHealth(n_paths=2, phi_steps=2, cooldown_steps=4,
                   max_staleness_epochs=None)
    assert h.admit_report(0, origin_epoch=0, now_epoch=0) == "admitted"
    assert h.admit_report(0, origin_epoch=0, now_epoch=3) == "duplicate"
    assert h.phi_of(0) == 2  # unchanged: duplicates are state-free


def test_seen_set_survives_state_round_trip():
    h = LinkHealth(n_paths=2, phi_steps=2, max_staleness_epochs=3)
    h.admit_report(0, origin_epoch=1, now_epoch=1)
    h2 = LinkHealth(n_paths=2, phi_steps=2, max_staleness_epochs=3)
    h2.restore(json.loads(json.dumps(h.state())))
    assert h2.admit_report(0, origin_epoch=1, now_epoch=2) == "duplicate"


# --------------------------------------------------------------- watchdog
def test_watchdog_state_machine():
    wd = TelemetryWatchdog(blackout_epochs=3)
    assert wd.observe(2) == "ok" and not wd.safe_mode
    assert wd.observe(0) == "silent"
    assert wd.observe(0) == "silent"
    assert wd.observe(0) == "safe" and wd.safe_mode
    assert wd.observe(0) == "safe"  # stays safe while silent
    assert wd.observe(1) == "recovered" and not wd.safe_mode
    assert wd.observe(0) == "silent"  # counter restarted after recovery


def test_watchdog_state_round_trip():
    wd = TelemetryWatchdog(blackout_epochs=2)
    wd.observe(0)
    wd2 = TelemetryWatchdog(blackout_epochs=2)
    wd2.restore(json.loads(json.dumps(wd.state())))
    assert wd2.observe(0) == "safe"  # one more silent epoch tips it


# --------------------------------------------- versioned plan application
def test_apply_plan_refuses_stale_and_duplicate_deliveries():
    p1 = collectives.PathPlan(directions=(1, -1), version=1)
    p2 = collectives.PathPlan(directions=(1, -1), inactive=(True, False),
                              version=2)
    cur, took = collectives.apply_plan(p1, p2)
    assert took and cur is p2
    # duplicated delivery of the applied plan: refused, state untouched
    cur2, took2 = collectives.apply_plan(cur, p2)
    assert not took2 and cur2 is p2
    # reordered delivery of the superseded plan: refused
    cur3, took3 = collectives.apply_plan(cur, p1)
    assert not took3 and cur3 is p2


def test_apply_plan_adversarial_delivery_order():
    # any interleaving of versions 1..5 with repeats must land on 5 and
    # never step backwards
    plans = {v: collectives.PathPlan(version=v) for v in range(1, 6)}
    deliveries = [3, 1, 4, 4, 2, 5, 3, 5, 1]
    cur = plans[1]
    seen_version = cur.version
    for v in deliveries:
        cur, took = collectives.apply_plan(cur, plans[v])
        assert cur.version >= seen_version
        assert took == (v > seen_version)
        seen_version = cur.version
    assert cur is plans[5]


def test_health_plan_stamps_version_from_step():
    h = LinkHealth(n_paths=2, phi_steps=2)
    assert h.plan(7).version == 7
    assert h.plan(7, version=3).version == 3


# --------------------------------------- campaign duplicate-event rejection
def test_campaign_rejects_duplicate_events():
    ev = LinkFlap(links=(1, 2), start_epoch=1, end_epoch=3)
    dup = LinkFlap(links=(1, 2), start_epoch=1, end_epoch=3, duty=0.9)
    with pytest.raises(AssertionError, match="duplicate campaign event"):
        FaultCampaign(events=(ev, dup))


def test_campaign_accepts_distinct_windows_on_same_links():
    ev1 = LinkFlap(links=(1,), start_epoch=1, end_epoch=3)
    ev2 = LinkFlap(links=(1,), start_epoch=3, end_epoch=5)
    FaultCampaign(events=(ev1, ev2))  # must not raise


def test_random_campaign_never_draws_duplicates():
    from repro.netsim import topology
    from repro.netsim.faults import _event_key, random_campaign

    topo = topology.leaf_spine(2, 4, 2, 40e9)
    for seed in range(12):
        c = random_campaign(topo, epochs=6, n_faults=5, seed=seed, n_ranks=8)
        keys = [_event_key(e) for e in c.events]
        assert len(keys) == len(set(keys)) == 5


# -------------------------------------------------- backoff jitter (sweep)
def test_retry_sleep_is_deterministic_and_decorrelated():
    from repro.netsim.sweep import retry_sleep_s

    a = retry_sleep_s(0, 1, backoff_s=1.0, jitter_frac=0.5)
    assert a == retry_sleep_s(0, 1, backoff_s=1.0, jitter_frac=0.5)
    assert 1.0 <= a <= 1.5
    # different jobs failing on the same attempt sleep different amounts —
    # the anti-synchronized-retry-storm property
    sleeps = {retry_sleep_s(i, 1, 1.0, 0.5) for i in range(8)}
    assert len(sleeps) == 8
    # exponential base still doubles under the jitter envelope
    assert retry_sleep_s(0, 3, 1.0, 0.0) == 4.0
    # the test fast path: zero backoff never sleeps
    assert retry_sleep_s(5, 4, 0.0, 0.5) == 0.0


# ---------------------------------------------------- co-sim driver twins
def _cosim_kw(topo):
    from repro.dist import cosim

    return dict(scheme="ecmp", epochs=6, phi_steps=2, n_chunks=8, seed=0,
                faults=(cosim.kill_spine(topo, 1, epoch=1, recover_epoch=3),))


@pytest.fixture(scope="module")
def small_topo():
    from repro.netsim import topology

    return topology.leaf_spine(2, 4, 2, 40e9)


def test_cosim_perfect_channel_matches_no_channel(small_topo):
    from repro.dist import cosim

    topo = small_topo
    hosts = cosim.ring_hosts(topo, 4)
    h0 = cosim.run_cosim(topo, hosts, 2e6, **_cosim_kw(topo))
    h1 = cosim.run_cosim(topo, hosts, 2e6, telemetry=TelemetryChannel(),
                         **_cosim_kw(topo))
    for a, b in zip(h0.records, h1.records):
        assert a.quarantined == b.quarantined
        assert a.reported_slow == b.reported_slow
        assert a.plan_churn == b.plan_churn
        assert a.completion == b.completion
        np.testing.assert_array_equal(a.fct, b.fct)
        assert not b.safe_mode
    assert h0.final_plan.inactive == h1.final_plan.inactive
    assert h1.plan_refused == 0
    # plan versions are strictly monotone across the whole run
    vs = [r.plan_version for r in h1.records]
    assert vs == sorted(vs) and len(set(vs)) == len(vs)


def test_cosim_blackout_trips_safe_mode_and_recovers(small_topo):
    from repro.dist import cosim

    topo = small_topo
    hosts = cosim.ring_hosts(topo, 4)
    h = cosim.run_cosim(
        topo, hosts, 2e6, scheme="ecmp", epochs=8, phi_steps=2, n_chunks=8,
        seed=0, telemetry=TelemetryChannel(blackout=(0, 4), seed=1),
        blackout_epochs=2,
        faults=(cosim.kill_spine(topo, 1, epoch=1, recover_epoch=6),))
    safe = [r.epoch for r in h.records if r.safe_mode]
    assert safe and min(safe) == 2  # k=2 silent epochs (0, 1) -> safe at 2
    # while safe the planner does not steer on stale state: no quarantines
    for r in h.records:
        if r.safe_mode:
            assert r.quarantined == ()
    # channel heals at 4 -> recovery; steering resumes and the run converges
    assert not h.records[-1].safe_mode
    assert any(r.quarantined for r in h.records[5:])
    assert h.records[-1].completion >= 1.0


def test_cosim_journal_schema_v2_and_refusal(tmp_path, small_topo):
    from repro.dist import cosim

    topo = small_topo
    hosts = cosim.ring_hosts(topo, 4)
    jp = os.path.join(tmp_path, "tele.jsonl")
    kw = dict(_cosim_kw(topo), epochs=3)
    cosim.run_cosim(topo, hosts, 2e6, journal=jp, **kw)
    lines = open(jp).read().splitlines()
    head = json.loads(lines[0])
    assert head["schema_version"] == cosim.JOURNAL_SCHEMA_VERSION == 2
    # an old-format journal (v1 header) refuses loudly instead of resuming
    head["schema_version"] = 1
    with open(jp, "w") as fh:
        fh.write(json.dumps(head) + "\n" + "\n".join(lines[1:]) + "\n")
    with pytest.raises(cosim.JournalSchemaError, match="schema_version=1"):
        cosim.run_cosim(topo, hosts, 2e6, journal=jp, **kw)


def test_cosim_telemetry_journal_resume_bit_identical(tmp_path, small_topo):
    from repro.dist import cosim

    topo = small_topo
    hosts = cosim.ring_hosts(topo, 4)
    jp = os.path.join(tmp_path, "tele_resume.jsonl")

    def mk_kw():
        return dict(_cosim_kw(topo),
                    telemetry=TelemetryChannel(loss=0.3, delay_epochs=1,
                                               dup=0.2, seed=5),
                    staleness_bound=2)

    h_full = cosim.run_cosim(topo, hosts, 2e6, journal=jp, **mk_kw())
    # tear the journal after epoch 2 and resume with a FRESH channel: the
    # journaled channel/watchdog state must carry the in-flight reports
    lines = open(jp).read().splitlines()
    with open(jp, "w") as fh:
        fh.write("\n".join(lines[:4]) + "\n" + lines[4][:40] + "\n")
    h_res = cosim.run_cosim(topo, hosts, 2e6, journal=jp, **mk_kw())
    for a, b in zip(h_res.records, h_full.records):
        assert a.epoch == b.epoch
        assert a.quarantined == b.quarantined
        assert a.reported_slow == b.reported_slow
        assert (a.reports_delivered, a.reports_admitted,
                a.reports_stale, a.reports_duplicate) == \
               (b.reports_delivered, b.reports_admitted,
                b.reports_stale, b.reports_duplicate)
        np.testing.assert_allclose(a.fct, b.fct, rtol=1e-6)
    assert h_res.final_plan.inactive == h_full.final_plan.inactive
