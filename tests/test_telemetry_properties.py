"""Hypothesis property tests for the degraded-telemetry invariants
(ISSUE 7):

  * a perfect TelemetryChannel (loss=0, delay=0, dup=0, no blackout) is
    bit-identical to no channel at all: every report delivered exactly
    once, in order, in its send epoch, so a LinkHealth fed through it
    matches one fed directly;
  * duplicate delivery is idempotent: admitting any report sequence with
    arbitrary repeats leaves LinkHealth in exactly the state of admitting
    the deduped sequence;
  * the staleness bound is monotone: every report a tighter bound admits,
    a looser bound admits too — so loosening the bound can only ADD
    quarantines, never drop one (with cooldown 0, where admission order
    cannot interact with flap hysteresis).

Hypothesis is an optional dependency (not in the CI image) — these skip
when it is absent; seeded spot checks of the same properties run
unconditionally in tests/test_telemetry.py.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dist.elastic import LinkHealth  # noqa: E402
from repro.netsim.faults import TelemetryChannel  # noqa: E402


def _health_key(h: LinkHealth) -> tuple:
    return (tuple(sorted(h._last_report.items())),
            tuple(sorted(h._phi.items())))


@settings(max_examples=60, deadline=None)
@given(
    sends=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 10)),
                   max_size=40),
    seed=st.integers(0, 5),
    phi=st.integers(1, 5),
)
def test_perfect_channel_is_bit_identical_to_no_channel(sends, seed, phi):
    ch = TelemetryChannel(seed=seed)  # all-default degradation = perfect
    direct = LinkHealth(n_paths=4, phi_steps=phi)
    via = LinkHealth(n_paths=4, phi_steps=phi)
    sends = sorted(sends, key=lambda s: s[1])
    for epoch in range(12):
        for path, e in sends:
            if e == epoch:
                direct.report_slow(path, epoch)
                ch.send(("slow", path), epoch)
        batch = ch.deliver(epoch)
        assert batch == [(("slow", p), e) for p, e in sends if e == epoch]
        for payload, origin in batch:
            assert origin == epoch  # no delay: arrives in its send epoch
            via.report_slow(payload[1], epoch)
    assert ch.sent == ch.delivered and ch.dropped == 0
    assert _health_key(direct) == _health_key(via)
    for step in range(16):
        assert direct.inactive(step) == via.inactive(step)


@settings(max_examples=60, deadline=None)
@given(
    reports=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 8), st.integers(0, 3)),
        max_size=30),
    bound=st.one_of(st.none(), st.integers(0, 6)),
    phi=st.integers(1, 5),
    cooldown=st.integers(0, 4),
)
def test_duplicate_delivery_is_idempotent(reports, bound, phi, cooldown):
    # reports: (path, origin, extra_delay); deliveries happen in epoch
    # order; duplicates = the same (path, origin) delivered again later
    deliveries = sorted(((p, o, o + d) for p, o, d in reports),
                        key=lambda r: r[2])
    once = LinkHealth(n_paths=4, phi_steps=phi, cooldown_steps=cooldown,
                      max_staleness_epochs=bound)
    twice = LinkHealth(n_paths=4, phi_steps=phi, cooldown_steps=cooldown,
                       max_staleness_epochs=bound)
    for p, o, now in deliveries:
        once.admit_report(p, o, now)
        twice.admit_report(p, o, now)
        v = twice.admit_report(p, o, now)  # immediate duplicate delivery
        assert v in ("duplicate", "stale")
    # and a full replay of the whole sequence afterwards is absorbed too
    last = max((now for _, _, now in deliveries), default=0)
    for p, o, now in deliveries:
        v = twice.admit_report(p, o, last)
        assert v in ("duplicate", "stale")
    assert _health_key(once) == _health_key(twice)


@settings(max_examples=60, deadline=None)
@given(
    reports=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 8), st.integers(0, 4)),
        max_size=30),
    tight=st.integers(0, 4),
    loosen=st.integers(0, 4),
    phi=st.integers(1, 5),
    probe=st.integers(0, 20),
)
def test_staleness_bound_is_monotone(reports, tight, loosen, phi, probe):
    # cooldown 0: admission cannot interact with flap hysteresis, so the
    # loose health's state dominates the tight one's pointwise
    a = LinkHealth(n_paths=4, phi_steps=phi, max_staleness_epochs=tight)
    b = LinkHealth(n_paths=4, phi_steps=phi,
                   max_staleness_epochs=tight + loosen)
    deliveries = sorted(((p, o, o + d) for p, o, d in reports),
                        key=lambda r: r[2])
    for p, o, now in deliveries:
        va = a.admit_report(p, o, now)
        vb = b.admit_report(p, o, now)
        if va == "admitted":  # the tight bound admits -> the loose one must
            assert vb in ("admitted", "duplicate")
        if vb == "stale":  # the loose bound rejects -> the tight one must
            assert va == "stale"
    # any path the tight health quarantines, the loose one quarantines too
    for qa, qb in zip(a.inactive(probe), b.inactive(probe)):
        assert qb or not qa
